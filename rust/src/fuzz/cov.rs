//! Cheap edge-counter instrumentation for coverage-guided fuzzing.
//!
//! The classic greybox trick (AFL's `trace_bits`, libFuzzer's inline
//! 8-bit counters) done dependency-free and opt-in: a thread-local
//! 64 KiB hit-count map, bumped by [`edge!`] probes hand-placed at the
//! guard/branch sites of the hot parsers (`model/container.rs`,
//! `serve/http.rs`, `cabac/decoder.rs`, `delta/*`). Each probe is keyed
//! by a *compile-time* FNV-1a hash of `module_path!() + "::" + name`,
//! so recording one edge is a thread-local index + saturating `u8`
//! increment — cheap enough to leave in the CABAC bin loop.
//!
//! # Zero cost without the feature
//!
//! Unless the `fuzz-cov` cargo feature is enabled, `edge!` expands to
//! an empty block and every function in this module is a no-op stub, so
//! `cargo build --release` produces byte-for-byte uninstrumented hot
//! paths. This is pinned at compile time by `_PROBE_IS_CONST_NOTHING`
//! below: the probe expansion must be const-evaluable (i.e. contain no
//! calls at all) whenever the feature is off.
//!
//! # Session discipline
//!
//! The map is thread-local and cumulative; the evolve loop calls
//! [`reset`] before each case and [`hot_slots`] after, giving a
//! deterministic per-case edge set (single-threaded execution, fixed
//! inputs — no wall-clock or address-space dependence anywhere).

/// Size of the hit-count map. 64 KiB, same order as AFL's default: big
/// enough that a few hundred hand-placed probes essentially never
/// collide (birthday bound ≈ 0.3 % for 200 probes), small enough to
/// scan after every case.
pub const MAP_SIZE: usize = 1 << 16;

/// Compile-time FNV-1a of the probe name, reduced to a map slot.
///
/// `const fn` so every `edge!` call site bakes its slot into the binary
/// as an immediate — no hashing at record time.
pub const fn edge_id(name: &str) -> usize {
    let bytes = name.as_bytes();
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut i = 0;
    while i < bytes.len() {
        h ^= bytes[i] as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
        i += 1;
    }
    (h % (MAP_SIZE as u64)) as usize
}

/// Record one edge hit. Named `edge!` at every call site; probes pass a
/// short string literal unique within their module, e.g.
/// `crate::fuzz::cov::edge!("layer_bad_chunks")`.
///
/// Expands to an empty block unless the `fuzz-cov` feature is on — the
/// name literal is consumed at compile time either way.
#[macro_export]
macro_rules! __cov_edge {
    ($name:literal) => {{
        #[cfg(feature = "fuzz-cov")]
        {
            const __SLOT: usize =
                $crate::fuzz::cov::edge_id(concat!(module_path!(), "::", $name));
            $crate::fuzz::cov::hit(__SLOT);
        }
    }};
}
pub use crate::__cov_edge as edge;

/// True when this build records coverage (the `fuzz-cov` feature).
pub const fn enabled() -> bool {
    cfg!(feature = "fuzz-cov")
}

// Compile-time pin of the no-op guarantee: with the feature off the
// probe must be const-evaluable *nothing* (an empty block). If anyone
// sneaks runtime work into the disabled expansion, `hit` is not a
// `const fn` and this item stops compiling.
#[cfg(not(feature = "fuzz-cov"))]
#[allow(clippy::let_unit_value)]
const _PROBE_IS_CONST_NOTHING: () = crate::fuzz::cov::edge!("noop_pin");

#[cfg(feature = "fuzz-cov")]
mod imp {
    use super::MAP_SIZE;
    use std::cell::RefCell;

    thread_local! {
        // Boxed so a thread that never fuzzes doesn't reserve 64 KiB of
        // TLS; allocated lazily on the first probe/reset of a thread.
        static MAP: RefCell<Box<[u8; MAP_SIZE]>> =
            RefCell::new(Box::new([0u8; MAP_SIZE]));
    }

    /// Saturating bump of one slot's hit counter.
    #[inline]
    pub fn hit(slot: usize) {
        MAP.with(|m| {
            let mut m = m.borrow_mut();
            let c = &mut m[slot % MAP_SIZE];
            *c = c.saturating_add(1);
        });
    }

    /// Zero the calling thread's map (start of a coverage session or of
    /// one per-case measurement).
    pub fn reset() {
        MAP.with(|m| m.borrow_mut().fill(0));
    }

    /// Slots with a nonzero hit count since the last [`reset`],
    /// ascending. Order is deterministic (index order), so two replays
    /// of the same inputs compare equal.
    pub fn hot_slots() -> Vec<usize> {
        MAP.with(|m| {
            m.borrow()
                .iter()
                .enumerate()
                .filter(|(_, &c)| c != 0)
                .map(|(i, _)| i)
                .collect()
        })
    }

    /// Number of distinct edges hit since the last [`reset`].
    pub fn unique_edges() -> usize {
        MAP.with(|m| m.borrow().iter().filter(|&&c| c != 0).count())
    }

    /// FNV-1a over the whole hit-count map — a cheap fingerprint for
    /// "two runs produced the identical coverage profile" assertions.
    pub fn map_hash() -> u64 {
        MAP.with(|m| {
            let mut h: u64 = 0xcbf2_9ce4_8422_2325;
            for &b in m.borrow().iter() {
                h ^= b as u64;
                h = h.wrapping_mul(0x0000_0100_0000_01b3);
            }
            h
        })
    }
}

#[cfg(not(feature = "fuzz-cov"))]
mod imp {
    //! Feature-off stubs: same signatures, no state, `const` where the
    //! compile-time pin needs it.

    #[inline]
    pub const fn hit(_slot: usize) {}

    pub fn reset() {}

    pub fn hot_slots() -> Vec<usize> {
        Vec::new()
    }

    pub fn unique_edges() -> usize {
        0
    }

    pub fn map_hash() -> u64 {
        0
    }
}

pub use imp::{hit, hot_slots, map_hash, reset, unique_edges};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn edge_ids_are_stable_and_in_range() {
        let a = edge_id("a");
        let b = edge_id("b");
        assert_eq!(a, edge_id("a"));
        assert_ne!(a, b);
        assert!(a < MAP_SIZE && b < MAP_SIZE);
    }

    #[test]
    fn probe_names_used_in_tree_do_not_collide() {
        // edge_id reduces a 64-bit hash mod 2^16; with tens of probes
        // the birthday bound is tiny but not zero, so pin the actual
        // in-tree probes pairwise-distinct — hashing the same
        // module-qualified strings the macro expands to (update if a
        // probe is added that collides: rename it, names are arbitrary).
        const M_CONTAINER: &str = "deepcabac::model::container";
        const M_HTTP: &str = "deepcabac::serve::http";
        const M_CABAC: &str = "deepcabac::cabac::decoder";
        const M_APPLY: &str = "deepcabac::delta::apply";
        const M_RESIDUAL: &str = "deepcabac::delta::residual";
        const M_PROGRESSIVE: &str = "deepcabac::delta::progressive";
        const M_COV: &str = "deepcabac::fuzz::cov";
        let probes: [(&str, &[&str]); 7] = [
            (M_CONTAINER, &[
                "prefix_bad_magic", "prefix_short", "prefix_bad_version",
                "prefix_v3_fp", "prefix_bad_tiers", "prefix_tier_len",
                "prefix_tier_overflow", "prefix_ok", "dlayer_coded",
                "dlayer_skip", "dlayer_bad_flag", "layer_bad_rank",
                "layer_bad_remainder", "layer_bad_chunks",
                "layer_chunk_canonical", "layer_too_many_weights",
                "layer_payload_density", "layer_level_density",
                "layer_chunk_overflow", "layer_chunk_tile", "layer_ok",
                "varint_overlong", "string_too_long",
                "tail_truncated_payload", "tail_truncated_bias",
                "tail_bias_too_big", "batch_v3_redirect",
                "batch_v4_redirect", "batch_trailing", "batch_ok",
                "v3_wrong_version", "v3_trailing", "v3_ok",
                "v4_wrong_version", "v4_tier0_span", "v4_truncated_tier",
                "v4_tier_span", "v4_trailing", "v4_ok",
            ]),
            (M_HTTP, &[
                "head_too_large", "head_not_utf8", "head_empty",
                "head_bad_request_line", "head_header_line", "head_ok",
                "range_absent", "range_not_bytes", "range_multi",
                "range_no_dash", "range_empty_pair", "range_suffix_bad",
                "range_suffix_zero", "range_suffix_ok", "range_open_bad",
                "range_open_ok", "range_closed_bad", "range_closed_ok",
                "range_unsat", "range_sat",
            ]),
            (M_CABAC, &[
                "cabac_mps", "cabac_lps", "cabac_renorm",
                "cabac_bypass_one", "cabac_eg_break",
            ]),
            (M_APPLY, &[
                "apply_fp_mismatch", "apply_ok", "sapply_not_delta",
                "sapply_fp_mismatch", "sapply_layer_count",
                "sapply_name_mismatch", "sapply_skip",
                "sapply_weight_count", "sapply_overflow",
            ]),
            (M_RESIDUAL, &[
                "rapply_weight_count", "rapply_residual_short",
                "rapply_overflow", "rapply_layer_count",
                "rapply_name_mismatch", "rapply_skip", "rapply_coded",
            ]),
            (M_PROGRESSIVE, &[
                "mat_tier_range", "papply_not_v4", "papply_extra_layer",
                "papply_name_mismatch", "papply_skip",
                "papply_weight_count", "papply_overflow", "papply_tier",
            ]),
            (M_COV, &["noop_pin"]),
        ];
        let mut slots = std::collections::BTreeSet::new();
        for (module, names) in probes {
            for n in names {
                let full = format!("{module}::{n}");
                assert!(
                    slots.insert(edge_id(&full)),
                    "probe {full:?} collides with an earlier slot"
                );
            }
        }
    }

    #[cfg(feature = "fuzz-cov")]
    #[test]
    fn hits_accumulate_and_reset() {
        reset();
        assert_eq!(unique_edges(), 0);
        edge!("cov_test_alpha");
        edge!("cov_test_alpha");
        edge!("cov_test_beta");
        assert_eq!(unique_edges(), 2);
        let hot = hot_slots();
        assert_eq!(hot.len(), 2);
        assert!(hot.windows(2).all(|w| w[0] < w[1]), "slots sorted");
        let h1 = map_hash();
        reset();
        assert_eq!(unique_edges(), 0);
        edge!("cov_test_alpha");
        edge!("cov_test_alpha");
        edge!("cov_test_beta");
        assert_eq!(map_hash(), h1, "same hits => same map hash");
    }

    #[cfg(feature = "fuzz-cov")]
    #[test]
    fn counters_saturate_instead_of_wrapping() {
        reset();
        for _ in 0..1000 {
            edge!("cov_test_saturate");
        }
        // still exactly one unique edge; the counter must not have
        // wrapped through zero (which would erase the edge)
        assert_eq!(unique_edges(), 1);
    }
}
