//! Grammar-driven input generators.
//!
//! Every generated input starts *syntactically valid* so the downstream
//! mutations (see [`super::mutate`]) land deep inside the parsers
//! instead of bouncing off the `DCBC` magic check or the request-line
//! split. Containers are built through the production encoder
//! ([`crate::codec::encode_levels`] + [`CompressedModel::serialize`]) —
//! never a hand-rolled writer that could drift from the format — and the
//! byte-offset field map the mutator needs is recovered by *re-walking*
//! the emitted bytes with the recording parser [`map_fields`], so the
//! offsets are correct by construction.

use crate::bitstream::read_varint;
use crate::codec::{encode_levels, CodecConfig, RemainderMode};
use crate::model::{
    ChunkInfo, CompressedLayer, CompressedModel, DeltaLayer, DeltaModel, ProgressiveModel,
};
use crate::quant::QuantGrid;
use crate::util::SplitMix64;
use anyhow::{bail, Result};

/// What a byte range inside a serialized container encodes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FieldKind {
    Magic,
    Version,
    /// v3 only: the 8 raw LE bytes of the parent fingerprint.
    ParentFp,
    /// v3 only: the per-layer 1-byte skip flag.
    SkipFlag,
    ModelNameLen,
    ModelName,
    LayerCount,
    /// v4 only: the declared tier count.
    TierCount,
    /// v4 only: one tier-table entry (the byte length of a tier body).
    TierByteLen,
    LayerNameLen,
    LayerName,
    DimCount,
    Dim,
    Delta,
    MaxLevel,
    SParam,
    CfgBytes,
    ChunkCount,
    ChunkWeights,
    ChunkBytes,
    NWeights,
    PayloadLen,
    Payload,
    BiasLen,
    BiasBytes,
}

impl FieldKind {
    /// True for fields stored as a LEB128 varint (resizable on rewrite).
    pub fn is_varint(self) -> bool {
        matches!(
            self,
            FieldKind::ModelNameLen
                | FieldKind::LayerCount
                | FieldKind::TierCount
                | FieldKind::TierByteLen
                | FieldKind::LayerNameLen
                | FieldKind::DimCount
                | FieldKind::Dim
                | FieldKind::MaxLevel
                | FieldKind::SParam
                | FieldKind::ChunkCount
                | FieldKind::ChunkWeights
                | FieldKind::ChunkBytes
                | FieldKind::NWeights
                | FieldKind::PayloadLen
                | FieldKind::BiasLen
        )
    }
}

/// One contiguous byte range of a serialized container.
#[derive(Debug, Clone, Copy)]
pub struct Field {
    pub offset: usize,
    pub len: usize,
    pub kind: FieldKind,
}

/// Recording walker: tiles `bytes` (a *valid* serialized container, e.g.
/// straight out of [`CompressedModel::serialize`]) into its [`Field`]s.
/// The fields are contiguous, in offset order, and cover every byte —
/// asserted by `fields_tile_the_container` below.
pub fn map_fields(bytes: &[u8]) -> Result<Vec<Field>> {
    let mut w = Walker { buf: bytes, pos: 0, fields: Vec::new() };
    w.raw(4, FieldKind::Magic)?;
    let version = w.buf.get(4).copied().unwrap_or(0);
    w.raw(1, FieldKind::Version)?;
    let delta_seg = version == crate::model::container::VERSION_DELTA;
    let progressive = version == crate::model::container::VERSION_PROGRESSIVE;
    if delta_seg {
        w.raw(8, FieldKind::ParentFp)?;
    }
    let name_len = w.varint(FieldKind::ModelNameLen)? as usize;
    w.raw(name_len, FieldKind::ModelName)?;
    let n_layers = w.varint(FieldKind::LayerCount)? as usize;
    if progressive {
        let n_tiers = w.varint(FieldKind::TierCount)? as usize;
        if n_tiers == 0 || n_tiers > crate::model::container::MAX_TIERS {
            bail!("field map: tier count {n_tiers} out of range");
        }
        for _ in 0..n_tiers {
            w.varint(FieldKind::TierByteLen)?;
        }
        // tier 0 is v2-shaped (always chunk-tabled), refinements are
        // v3 dlayer records — same tiling the batch parser walks
        for _ in 0..n_layers {
            w.layer_record(true)?;
        }
        for _ in 1..n_tiers {
            for _ in 0..n_layers {
                w.dlayer_record()?;
            }
        }
    } else {
        for _ in 0..n_layers {
            if delta_seg {
                w.dlayer_record()?;
                continue;
            }
            w.layer_record(version == crate::model::container::VERSION_CHUNKED)?;
        }
    }
    if w.pos != bytes.len() {
        bail!("field map: {} trailing bytes", bytes.len() - w.pos);
    }
    Ok(w.fields)
}

/// First byte offset past the container prelude (magic, version, model
/// name, layer count) — mutations before this point mostly die at the
/// magic/version check, so the mutator biases past it.
pub fn prelude_end(fields: &[Field]) -> usize {
    fields
        .iter()
        .find(|f| f.kind == FieldKind::LayerCount)
        .map(|f| f.offset + f.len)
        .unwrap_or(0)
}

struct Walker<'a> {
    buf: &'a [u8],
    pos: usize,
    fields: Vec<Field>,
}

impl Walker<'_> {
    fn raw(&mut self, n: usize, kind: FieldKind) -> Result<()> {
        if self.buf.len() - self.pos < n {
            bail!("field map: truncated {kind:?}");
        }
        if n > 0 {
            self.fields.push(Field { offset: self.pos, len: n, kind });
        }
        self.pos += n;
        Ok(())
    }

    fn varint(&mut self, kind: FieldKind) -> Result<u64> {
        let Some((v, n)) = read_varint(&self.buf[self.pos..]) else {
            bail!("field map: bad varint for {kind:?}");
        };
        self.fields.push(Field { offset: self.pos, len: n, kind });
        self.pos += n;
        Ok(v)
    }

    /// One full layer record (v1 shape, or v2/v3/v4 with a chunk table).
    fn layer_record(&mut self, chunked: bool) -> Result<()> {
        let lname = self.varint(FieldKind::LayerNameLen)? as usize;
        self.raw(lname, FieldKind::LayerName)?;
        let ndims = self.varint(FieldKind::DimCount)? as usize;
        for _ in 0..ndims {
            self.varint(FieldKind::Dim)?;
        }
        self.raw(4, FieldKind::Delta)?;
        self.varint(FieldKind::MaxLevel)?;
        self.varint(FieldKind::SParam)?;
        self.raw(4, FieldKind::CfgBytes)?;
        if chunked {
            let n_chunks = self.varint(FieldKind::ChunkCount)? as usize;
            if n_chunks > crate::model::container::MAX_CHUNKS {
                bail!("field map: chunk count {n_chunks} out of range");
            }
            for _ in 0..n_chunks {
                self.varint(FieldKind::ChunkWeights)?;
                self.varint(FieldKind::ChunkBytes)?;
            }
        }
        self.varint(FieldKind::NWeights)?;
        let payload_len = self.varint(FieldKind::PayloadLen)? as usize;
        self.raw(payload_len, FieldKind::Payload)?;
        let bias_len = self.varint(FieldKind::BiasLen)? as usize;
        let Some(bias_bytes) = bias_len.checked_mul(4) else {
            bail!("field map: bias length overflow");
        };
        self.raw(bias_bytes, FieldKind::BiasBytes)
    }

    /// One v3/v4 dlayer record: skip flag, then either a bare name or a
    /// full chunk-tabled layer record.
    fn dlayer_record(&mut self) -> Result<()> {
        let skip = self.buf.get(self.pos).copied().unwrap_or(u8::MAX);
        self.raw(1, FieldKind::SkipFlag)?;
        match skip {
            0 => self.layer_record(true),
            1 => {
                let lname = self.varint(FieldKind::LayerNameLen)? as usize;
                self.raw(lname, FieldKind::LayerName)
            }
            v => bail!("field map: bad delta skip flag {v}"),
        }
    }
}

// ---------------------------------------------------------------------------
// Generators
// ---------------------------------------------------------------------------

fn rand_levels(rng: &mut SplitMix64, n: usize) -> Vec<i32> {
    let p_zero = 0.4 + rng.next_f64() * 0.55;
    let spread = 1 + rng.below(60);
    (0..n)
        .map(|_| {
            if rng.next_f64() < p_zero {
                0
            } else {
                (1 + rng.below(spread) as i32) * if rng.next_u64() & 1 == 0 { 1 } else { -1 }
            }
        })
        .collect()
}

fn rand_layer(rng: &mut SplitMix64, idx: usize) -> CompressedLayer {
    let n = rng.below(220) as usize;
    let levels = rand_levels(rng, n);
    let cfg = CodecConfig {
        n_abs_flags: 1 + rng.below(14) as u32,
        remainder: RemainderMode::ExpGolomb(rng.below(3) as u32),
        sig_ctx_neighbors: rng.next_u64() & 1 == 0,
    };
    // chunk some layers so version-2 tables appear in the corpus
    let n_chunks = if rng.next_f64() < 0.4 && levels.len() >= 4 {
        2 + rng.below(4) as usize
    } else {
        1
    };
    let per = ((levels.len() + n_chunks - 1) / n_chunks).max(1);
    let mut payload = Vec::new();
    let mut chunks = Vec::new();
    for part in levels.chunks(per) {
        let bytes = encode_levels(part, cfg);
        chunks.push(ChunkInfo { n_weights: part.len(), bytes: bytes.len() });
        payload.extend_from_slice(&bytes);
    }
    if chunks.len() <= 1 {
        chunks.clear();
    }
    let max_abs = levels.iter().map(|l| l.unsigned_abs()).max().unwrap_or(0);
    CompressedLayer {
        name: format!("layer{idx}"),
        dims: vec![levels.len().max(1)],
        grid: QuantGrid { delta: 0.01 + rng.next_f32(), max_level: max_abs as i32 },
        s_param: rng.below(300) as u32,
        cfg,
        n_weights: levels.len(),
        payload,
        chunks,
        bias: (0..rng.below(12) as usize).map(|_| rng.next_f32() - 0.5).collect(),
    }
}

/// A syntactically valid serialized container (v1 or v2, 0–4 layers,
/// mixed monolithic/chunked, real CABAC payloads).
pub fn container(rng: &mut SplitMix64) -> Vec<u8> {
    let n_layers = rng.below(5) as usize;
    let layers = (0..n_layers).map(|i| rand_layer(rng, i)).collect();
    CompressedModel { name: format!("m{}", rng.below(1000)), layers }.serialize()
}

/// A syntactically valid serialized v3 delta segment (0–4 layers, mixed
/// skip/coded records, real CABAC residual payloads), built through the
/// production [`DeltaModel::serialize`] like [`container`] is.
pub fn delta_container(rng: &mut SplitMix64) -> Vec<u8> {
    let n_layers = rng.below(5) as usize;
    let layers = (0..n_layers)
        .map(|i| {
            if rng.next_f64() < 0.35 {
                DeltaLayer::Skipped(format!("layer{i}"))
            } else {
                DeltaLayer::Coded(rand_layer(rng, i))
            }
        })
        .collect();
    DeltaModel {
        parent_fp: rng.next_u64(),
        name: format!("m{}", rng.below(1000)),
        layers,
    }
    .serialize()
}

/// A syntactically valid serialized v4 progressive container (1–3
/// layers, 1–4 tiers, refinement records mixing skip/coded), built
/// through the production [`ProgressiveModel::serialize`]. Always at
/// least one layer: a zero-layer model's refinement tier bodies are
/// empty, so the parser's truncation rule collapses them and the
/// serialized form would not be canonical (the zero-layer accept path
/// is covered by the `accept_v4_zero_layers` corpus case instead).
pub fn progressive_container(rng: &mut SplitMix64) -> Vec<u8> {
    let n_layers = 1 + rng.below(3) as usize;
    let n_tiers = 1 + rng.below(4) as usize;
    let base: Vec<CompressedLayer> = (0..n_layers).map(|i| rand_layer(rng, i)).collect();
    let refinements = (1..n_tiers)
        .map(|_| {
            (0..n_layers)
                .map(|i| {
                    if rng.next_f64() < 0.35 {
                        DeltaLayer::Skipped(format!("layer{i}"))
                    } else {
                        DeltaLayer::Coded(rand_layer(rng, i))
                    }
                })
                .collect()
        })
        .collect();
    ProgressiveModel { name: format!("m{}", rng.below(1000)), base, refinements }.serialize()
}

// ---------------------------------------------------------------------------
// Encoder-side hostile models
// ---------------------------------------------------------------------------

/// Finite-but-nasty weight values: signed zeros, subnormals, the normal/
/// subnormal boundary, and full-range magnitudes. Safe to push through
/// [`crate::coordinator::pipeline::compress_model`], which assumes
/// finite input.
const HOSTILE_FINITE: [f32; 12] = [
    0.0,
    -0.0,
    1e-40,  // subnormal
    -1e-40, // subnormal
    f32::MIN_POSITIVE,
    -f32::MIN_POSITIVE,
    f32::MAX,
    f32::MIN,
    1.0,
    -1.0,
    0.05,
    -3.4e-20,
];

/// The full menu, including the values [`crate::tensor::validate_finite`]
/// must reject with a structured error (never a panic).
const HOSTILE_ANY: [f32; 15] = [
    0.0,
    -0.0,
    1e-40,
    -1e-40,
    f32::MIN_POSITIVE,
    -f32::MIN_POSITIVE,
    f32::MAX,
    f32::MIN,
    1.0,
    -1.0,
    0.05,
    -3.4e-20,
    f32::NAN,
    f32::INFINITY,
    f32::NEG_INFINITY,
];

/// Byte-driven selector stream: reads input bytes in order, yielding 0
/// once exhausted — total on any input, and ddmin-friendly (deleting a
/// suffix degrades the recipe gracefully instead of invalidating it).
struct Recipe<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl Recipe<'_> {
    fn byte(&mut self) -> u8 {
        let b = self.buf.get(self.pos).copied().unwrap_or(0);
        self.pos += 1;
        b
    }
}

/// Decode arbitrary fuzz bytes into a deterministic (parent, target)
/// model pair with a matching architecture — the hostile feedstock for
/// the `encoder` fuzz target.
///
/// The parent draws only from [`HOSTILE_FINITE`] (it must survive the
/// standard pipeline to become a base container); the target mixes in
/// NaN/±Inf from [`HOSTILE_ANY`], which the delta encoder's
/// `validate_finite` boundary must reject without panicking. Layer
/// shapes include zero-dim tensors and sizes up to 4096, capped so a
/// case stays inside the fuzz time budget.
pub fn hostile_model_pair(input: &[u8]) -> (crate::model::Model, crate::model::Model) {
    use crate::model::manifest::{LayerInfo, LayerKind, ModelManifest};
    use crate::tensor::Tensor;
    let mut r = Recipe { buf: input, pos: 0 };
    let n_layers = (r.byte() % 4) as usize;
    let mut elem_budget = 1usize << 13;
    let mut manifest_layers = Vec::new();
    let (mut pw, mut pb, mut ps) = (Vec::new(), Vec::new(), Vec::new());
    let (mut tw, mut tb, mut ts) = (Vec::new(), Vec::new(), Vec::new());
    for li in 0..n_layers {
        let n = match r.byte() % 8 {
            0 => 0, // zero-dim tensor
            1 => 1,
            2 => 1 + r.byte() as usize,
            3 | 4 => 1 + r.byte() as usize * 7,
            5 => 1024,
            _ => 4096,
        }
        .min(elem_budget);
        elem_budget -= n;
        let mut parent_w = Vec::with_capacity(n);
        let mut target_w = Vec::with_capacity(n);
        let mut sigma = Vec::with_capacity(n);
        for _ in 0..n {
            let sel = r.byte();
            parent_w.push(HOSTILE_FINITE[sel as usize % HOSTILE_FINITE.len()]);
            // ~3/4 of target entries keep the parent's value (a sparse
            // update), the rest re-draw — possibly non-finite
            let t = r.byte();
            target_w.push(if t % 4 != 0 {
                *parent_w.last().unwrap()
            } else {
                HOSTILE_ANY[(t / 4) as usize % HOSTILE_ANY.len()]
            });
            sigma.push(HOSTILE_FINITE[r.byte() as usize % HOSTILE_FINITE.len()].abs());
        }
        let n_bias = (r.byte() % 4) as usize;
        let bias: Vec<f32> =
            (0..n_bias).map(|_| HOSTILE_FINITE[r.byte() as usize % HOSTILE_FINITE.len()]).collect();
        manifest_layers.push(LayerInfo {
            name: format!("h{li}"),
            kind: LayerKind::Fc,
            shape: vec![n],
            activation: None,
            stride: 1,
            padding: 0,
            nonzero: 0,
            size: n,
        });
        pw.push(Tensor::new(vec![n], parent_w));
        ps.push(Tensor::new(vec![n], sigma.clone()));
        pb.push(Tensor::new(vec![n_bias], bias.clone()));
        tw.push(Tensor::new(vec![n], target_w));
        ts.push(Tensor::new(vec![n], sigma));
        tb.push(Tensor::new(vec![n_bias], bias));
    }
    let manifest = ModelManifest {
        name: "hostile".into(),
        task: "classify".into(),
        input_shape: vec![1],
        eval_batch: 1,
        n_classes: 2,
        param_count: 0,
        density: 1.0,
        dense_metric: 1.0,
        sparse_metric: 1.0,
        layers: manifest_layers,
        hlo: String::new(),
        arg_order: Vec::new(),
    };
    let parent = crate::model::Model {
        manifest: manifest.clone(),
        weights: pw,
        biases: pb,
        sigmas: ps,
    };
    let target =
        crate::model::Model { manifest, weights: tw, biases: tb, sigmas: ts };
    (parent, target)
}

// ---------------------------------------------------------------------------
// Delta-apply (parent, delta) pairs
// ---------------------------------------------------------------------------

/// Frame a (parent, delta) pair into one fuzz input: 4-byte LE parent
/// length, parent bytes, delta bytes. The inverse is
/// [`split_delta_pair`], which stays total under mutation by clamping
/// the declared length.
pub fn frame_delta_pair(parent: &[u8], delta: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(4 + parent.len() + delta.len());
    out.extend_from_slice(&(parent.len() as u32).to_le_bytes());
    out.extend_from_slice(parent);
    out.extend_from_slice(delta);
    out
}

/// Split a framed fuzz input back into (parent, delta) byte slices.
/// Total on any input: fewer than 4 bytes yields two empty slices, and a
/// lying length prefix is clamped to what is actually present (the
/// mutator flips length bytes like any others).
pub fn split_delta_pair(input: &[u8]) -> (&[u8], &[u8]) {
    if input.len() < 4 {
        return (&[], &[]);
    }
    let declared = u32::from_le_bytes([input[0], input[1], input[2], input[3]]) as usize;
    let plen = declared.min(input.len() - 4);
    (&input[4..4 + plen], &input[4 + plen..])
}

/// A *pristine* (parent, delta) pair as serialized bytes: the parent is
/// a generated container, the target perturbs a few of its decoded
/// levels (and sometimes a bias), and the delta is produced by the
/// production [`crate::delta::encode`] — so `apply(parent, delta)`
/// reconstructs the target byte-exactly.
pub fn delta_apply_parts(rng: &mut SplitMix64) -> (Vec<u8>, Vec<u8>) {
    let parent_bytes = container(rng);
    let parent = CompressedModel::deserialize(&parent_bytes)
        .expect("generator output must parse");
    let mut target = parent.clone();
    for tl in &mut target.layers {
        if rng.next_f64() < 0.3 {
            continue; // leave some layers byte-identical → skip records
        }
        let mut levels = tl.decode_levels_with(1);
        let tweaks = 1 + rng.below(4) as usize;
        for _ in 0..tweaks.min(levels.len()) {
            let i = rng.below(levels.len().max(1) as u64) as usize;
            levels[i] += if rng.next_u64() & 1 == 0 { 1 } else { -1 };
        }
        if !tl.bias.is_empty() && rng.next_f64() < 0.25 {
            let i = rng.below(tl.bias.len() as u64) as usize;
            tl.bias[i] += 0.25;
        }
        let splits: Vec<usize> = tl.chunk_spans().iter().map(|s| s.n_weights).collect();
        let (payload, chunks) =
            crate::delta::residual::encode_with_splits(&levels, tl.cfg, &splits);
        tl.payload = payload;
        tl.chunks = chunks;
    }
    let (delta, _report) =
        crate::delta::encode(&parent, &target, 1).expect("matched pair must delta-encode");
    (parent_bytes, delta.serialize())
}

/// A framed delta-apply fuzz input. 1-in-8 draws keep the parent
/// pristine (the pair must apply byte-exactly); the rest mutate the
/// parent *after* the delta captured its fingerprint — byte noise,
/// structured field lies via [`map_fields`] + the container mutator, or
/// truncation — probing the trust boundary `delta::apply` guards with
/// the fingerprint check.
pub fn delta_apply_pair(rng: &mut SplitMix64) -> Vec<u8> {
    let (mut parent, delta) = delta_apply_parts(rng);
    match rng.below(8) {
        0 => {} // pristine: apply must succeed and round-trip
        1 | 2 | 3 => {
            // raw byte noise anywhere in the parent (including its
            // header — a wrong version or magic must reject cleanly)
            let flips = 1 + rng.below(4) as usize;
            for _ in 0..flips {
                if parent.is_empty() {
                    break;
                }
                let i = rng.below(parent.len() as u64) as usize;
                parent[i] ^= 1 << rng.below(8);
            }
        }
        4 | 5 => {
            // structured lies: chunk tables, varint lengths, payload
            // splices — the same field-aware ops the container target uses
            if let Ok(fields) = map_fields(&parent) {
                parent = super::mutate::container(&parent, &fields, rng);
            } else {
                parent.truncate(parent.len() / 2);
            }
        }
        _ => {
            // truncation: the parent ends mid-record
            let keep = rng.below(parent.len().max(1) as u64) as usize;
            parent.truncate(keep);
        }
    }
    frame_delta_pair(&parent, &delta)
}

/// A syntactically valid HTTP/1.1 request head (no terminating blank
/// line — the shape [`crate::serve::http::parse_request_head`] takes),
/// covering every route the server exposes plus Range headers.
pub fn http_request(rng: &mut SplitMix64) -> Vec<u8> {
    let model = ["lenet5", "tiny", "m0"][rng.below(3) as usize];
    let layer = rng.below(5);
    let path = match rng.below(7) {
        0 => "/healthz".to_string(),
        1 => "/stats".to_string(),
        2 => "/models".to_string(),
        3 => format!("/models/{model}"),
        4 => format!("/models/{model}/manifest"),
        5 => format!("/models/{model}/layers/{layer}"),
        _ => format!("/models/{model}/layers/{layer}/weights"),
    };
    let mut head = format!("GET {path} HTTP/1.1\r\nHost: 127.0.0.1:8080\r\n");
    if rng.next_f64() < 0.5 {
        head.push_str(&format!("Range: {}\r\n", range_value(rng)));
    }
    if rng.next_f64() < 0.3 {
        head.push_str("Accept: */*\r\n");
    }
    head.push_str("Connection: close\r\n");
    head.into_bytes()
}

/// A syntactically valid `Range` header value (`bytes=` forms from RFC
/// 7233 — closed, open-ended, and suffix ranges).
pub fn range_value(rng: &mut SplitMix64) -> String {
    let a = rng.below(1 << 20);
    let b = a + rng.below(1 << 20);
    match rng.below(3) {
        0 => format!("bytes={a}-{b}"),
        1 => format!("bytes={a}-"),
        _ => format!("bytes=-{}", 1 + rng.below(1 << 20)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fields_tile_the_container() {
        // the recorded map must cover every byte, contiguously, for both
        // container versions — this is what makes mutation offsets sound
        let mut rng = SplitMix64::new(11);
        let (mut saw_v1, mut saw_v2) = (false, false);
        for _ in 0..32 {
            let bytes = container(&mut rng);
            match bytes[4] {
                crate::model::container::VERSION => saw_v1 = true,
                crate::model::container::VERSION_CHUNKED => saw_v2 = true,
                v => panic!("unexpected version {v}"),
            }
            let fields = map_fields(&bytes).unwrap();
            let mut pos = 0usize;
            for f in &fields {
                assert_eq!(f.offset, pos, "gap before {:?}", f.kind);
                assert!(f.len > 0);
                pos += f.len;
            }
            assert_eq!(pos, bytes.len());
            let pe = prelude_end(&fields);
            assert!(pe >= 6 && pe <= bytes.len());
        }
        assert!(saw_v1 && saw_v2, "generator must exercise both versions");
    }

    #[test]
    fn generated_containers_parse_and_roundtrip() {
        let mut rng = SplitMix64::new(23);
        for _ in 0..16 {
            let bytes = container(&mut rng);
            let m = CompressedModel::deserialize(&bytes).unwrap();
            assert_eq!(m.serialize(), bytes, "serializer output must be canonical");
        }
    }

    #[test]
    fn delta_fields_tile_and_roundtrip() {
        // the v3 field map must cover every byte of a delta segment —
        // skip records and coded records alike — and the generator's
        // output must be canonical through DeltaModel
        let mut rng = SplitMix64::new(31);
        let (mut saw_skip, mut saw_coded) = (false, false);
        for _ in 0..32 {
            let bytes = delta_container(&mut rng);
            assert_eq!(bytes[4], crate::model::container::VERSION_DELTA);
            let fields = map_fields(&bytes).unwrap();
            let mut pos = 0usize;
            for f in &fields {
                assert_eq!(f.offset, pos, "gap before {:?}", f.kind);
                pos += f.len;
            }
            assert_eq!(pos, bytes.len());
            assert!(fields.iter().any(|f| f.kind == FieldKind::ParentFp));
            for f in &fields {
                if f.kind == FieldKind::SkipFlag {
                    match bytes[f.offset] {
                        0 => saw_coded = true,
                        1 => saw_skip = true,
                        v => panic!("generator emitted bad skip flag {v}"),
                    }
                }
            }
            let dm = DeltaModel::deserialize(&bytes).unwrap();
            assert_eq!(dm.serialize(), bytes, "v3 serializer output must be canonical");
        }
        assert!(saw_skip && saw_coded, "generator must mix skip and coded records");
    }

    #[test]
    fn progressive_fields_tile_and_roundtrip() {
        // the v4 field map must cover every byte — tier table, base
        // records, and refinement dlayers — so mutations reach tier
        // handling; generator output must be canonical
        let mut rng = SplitMix64::new(37);
        let mut saw_multi_tier = false;
        for _ in 0..32 {
            let bytes = progressive_container(&mut rng);
            assert_eq!(bytes[4], crate::model::container::VERSION_PROGRESSIVE);
            let fields = map_fields(&bytes).unwrap();
            let mut pos = 0usize;
            for f in &fields {
                assert_eq!(f.offset, pos, "gap before {:?}", f.kind);
                pos += f.len;
            }
            assert_eq!(pos, bytes.len());
            let n_tiers =
                fields.iter().filter(|f| f.kind == FieldKind::TierByteLen).count();
            assert!(fields.iter().any(|f| f.kind == FieldKind::TierCount));
            assert!((1..=crate::model::container::MAX_TIERS).contains(&n_tiers));
            if n_tiers > 1 {
                saw_multi_tier = true;
            }
            let pm = ProgressiveModel::deserialize(&bytes).unwrap();
            assert_eq!(pm.n_tiers(), n_tiers);
            assert_eq!(pm.serialize(), bytes, "v4 serializer output must be canonical");
        }
        assert!(saw_multi_tier, "generator must emit refinement tiers");
    }

    #[test]
    fn hostile_model_pairs_are_total_and_matched() {
        // any byte string decodes to a structurally matched (parent,
        // target) pair, deterministically — including the empty input
        let mut rng = SplitMix64::new(17);
        for case in 0..24 {
            let input: Vec<u8> =
                (0..rng.below(600)).map(|_| rng.next_u64() as u8).collect();
            let (p, t) = hostile_model_pair(&input);
            let (p2, t2) = hostile_model_pair(&input);
            assert_eq!(p.weights.len(), t.weights.len());
            assert_eq!(p.manifest.layers.len(), p.weights.len());
            for (a, b) in p.weights.iter().zip(&t.weights) {
                assert_eq!(a.len(), b.len(), "case {case}: architecture drifted");
                // parent weights must be pipeline-safe
                assert!(a.data.iter().all(|v| v.is_finite()));
            }
            for (a, b) in p.weights.iter().zip(&p2.weights) {
                assert_eq!(
                    a.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    b.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    "recipe decode must be deterministic"
                );
            }
            assert_eq!(t.weights.len(), t2.weights.len());
        }
        let (p, _) = hostile_model_pair(&[]);
        assert!(p.weights.is_empty(), "empty recipe → zero-layer model");
    }

    #[test]
    fn delta_pair_framing_round_trips_and_splits_totally() {
        let (p, d) = (vec![1u8, 2, 3], vec![9u8, 8]);
        let framed = frame_delta_pair(&p, &d);
        assert_eq!(split_delta_pair(&framed), (&p[..], &d[..]));
        // total on garbage: short inputs and lying length prefixes
        assert_eq!(split_delta_pair(&[]), (&[][..], &[][..]));
        assert_eq!(split_delta_pair(&[1, 2, 3]), (&[][..], &[][..]));
        let lying = frame_delta_pair(&[0xAA; 8], &[]);
        let mut cut = lying.clone();
        cut.truncate(7); // declared 8 parent bytes, only 3 present
        let (pp, dd) = split_delta_pair(&cut);
        assert_eq!(pp.len(), 3);
        assert!(dd.is_empty());
        // empty-parent frame keeps the delta intact
        let (pp, dd) = split_delta_pair(&frame_delta_pair(&[], &d));
        assert!(pp.is_empty());
        assert_eq!(dd, &d[..]);
    }

    #[test]
    fn pristine_delta_parts_apply_byte_exactly() {
        let mut rng = SplitMix64::new(41);
        for _ in 0..8 {
            let (pb, db) = delta_apply_parts(&mut rng);
            let parent = CompressedModel::deserialize(&pb).unwrap();
            let delta = DeltaModel::deserialize(&db).unwrap();
            let applied = crate::delta::apply(&parent, &delta, 1).unwrap();
            // the applied model is canonical under its own serializer
            let y = applied.serialize();
            assert_eq!(CompressedModel::deserialize(&y).unwrap().serialize(), y);
        }
    }

    #[test]
    fn generated_requests_parse() {
        let mut rng = SplitMix64::new(5);
        for _ in 0..32 {
            let head = http_request(&mut rng);
            let req = crate::serve::http::parse_request_head(&head).unwrap();
            assert_eq!(req.method, "GET");
            assert!(req.path.starts_with('/'));
        }
    }

    #[test]
    fn generated_ranges_are_syntactically_valid() {
        let mut rng = SplitMix64::new(9);
        for _ in 0..32 {
            let v = range_value(&mut rng);
            assert!(v.starts_with("bytes="));
            // against a body larger than any generated bound, every
            // generated form must be satisfiable — i.e. truly valid
            let req = crate::serve::http::parse_request_head(
                format!("GET / HTTP/1.1\r\nRange: {v}\r\n").as_bytes(),
            )
            .unwrap();
            assert!(matches!(
                req.byte_range(1 << 21),
                crate::serve::http::RangeOutcome::Satisfiable(_)
            ), "{v}");
        }
    }
}
