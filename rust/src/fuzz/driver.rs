//! The fuzz driver: runs inputs against parser targets under the
//! crash invariants, minimizes reproducers, and replays the checked-in
//! corpus.
//!
//! A "crash" is any violation of the invariants every parser promises on
//! arbitrary bytes:
//!
//! * **never panic** — every case runs under `catch_unwind`;
//! * **never allocate beyond budget** — per-thread peak from
//!   [`super::alloc`], enforced only when the metering allocator is
//!   installed (see [`super::alloc::probe`]);
//! * **never loop** — a per-case wall-clock budget;
//! * **decode–reencode idempotence** — an *accepted* container must
//!   re-serialize to a fixpoint and its layers must decode to exactly
//!   `n_weights` levels, and batch-accept implies stream-accept.
//!
//! Reproducers are shrunk by a deterministic ddmin-style chunk-removal
//! pass before being written out, so corpus entries stay reviewable.

use super::{alloc, gen, mutate};
use crate::coordinator::pipeline::{compress_model, CompressionSpec};
use crate::model::container::{parse_container_prefix, Parsed, VERSION_DELTA, VERSION_PROGRESSIVE};
use crate::model::{CompressedModel, DeltaModel, ProgressiveModel};
use crate::serve::http::parse_request_head;
use crate::serve::stream::StreamDecoder;
use crate::util::{fnv1a, SplitMix64};
use anyhow::{Context, Result};
use std::cell::Cell;
use std::path::Path;
use std::time::Instant;

/// Which parser surface a fuzz case is thrown at.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TargetKind {
    /// Batch container parsing: [`CompressedModel::deserialize`] (or
    /// [`DeltaModel::deserialize`] for v3 inputs,
    /// [`ProgressiveModel::deserialize`] for v4) plus the
    /// roundtrip/idempotence invariants.
    Container,
    /// The push-based [`StreamDecoder`], fed in input-derived splits.
    Stream,
    /// [`parse_request_head`] plus Range evaluation on the result.
    Http,
    /// `Range` header value evaluation across body sizes.
    Range,
    /// The *encoder* side: hostile models (denormals, ±0, NaN/Inf,
    /// zero-dim/huge-dim tensors, decoded from the input bytes by
    /// [`gen::hostile_model_pair`]) pushed through the compression
    /// pipeline and [`crate::delta::encode_from_model`]. Non-finite
    /// input must be rejected with a structured error, and every
    /// accepted delta must apply back to the target byte-for-byte.
    Encoder,
    /// The delta-apply trust boundary: a framed (parent, delta) pair
    /// (see [`gen::split_delta_pair`]) where the parent container was
    /// typically mutated *after* the delta's fingerprint was taken —
    /// byte noise, chunk-table lies, truncation. [`crate::delta::apply`]
    /// must reject with a structured error or produce a byte-sane
    /// container (canonical, stream-apply-identical); never panic or
    /// blow the alloc budget on a lying parent.
    DeltaApply,
}

impl TargetKind {
    pub fn as_str(self) -> &'static str {
        match self {
            TargetKind::Container => "container",
            TargetKind::Stream => "stream",
            TargetKind::Http => "http",
            TargetKind::Range => "range",
            TargetKind::Encoder => "encoder",
            TargetKind::DeltaApply => "delta_apply",
        }
    }

    pub fn all() -> [TargetKind; 6] {
        [
            TargetKind::Container,
            TargetKind::Stream,
            TargetKind::Http,
            TargetKind::Range,
            TargetKind::Encoder,
            TargetKind::DeltaApply,
        ]
    }
}

/// Per-case resource budgets.
#[derive(Debug, Clone, Copy)]
pub struct Budgets {
    /// Peak live bytes a single case may allocate (checked only when the
    /// metering allocator is installed).
    pub alloc_bytes: usize,
    /// Wall-clock ceiling per case.
    pub millis: u64,
}

impl Default for Budgets {
    fn default() -> Self {
        Self { alloc_bytes: 64 << 20, millis: 2000 }
    }
}

/// How a case violated the invariants.
#[derive(Debug, Clone)]
pub enum CrashKind {
    /// The target panicked (message attached).
    Panic(String),
    /// Peak allocation exceeded the budget (actual peak attached).
    AllocBudget(usize),
    /// The case overran its wall-clock budget (elapsed ms attached).
    TimeBudget(u64),
    /// A corpus `accept_`/`reject_` expectation failed (regression).
    Expectation(String),
}

impl std::fmt::Display for CrashKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CrashKind::Panic(m) => write!(f, "panic: {m}"),
            CrashKind::AllocBudget(p) => write!(f, "alloc budget exceeded: peak {p} bytes"),
            CrashKind::TimeBudget(ms) => write!(f, "time budget exceeded: {ms} ms"),
            CrashKind::Expectation(m) => write!(f, "corpus expectation failed: {m}"),
        }
    }
}

/// One minimized reproducer.
#[derive(Debug, Clone)]
pub struct Crash {
    pub target: TargetKind,
    pub kind: CrashKind,
    /// The (minimized, for generated cases) input that triggers it.
    pub input: Vec<u8>,
}

/// Aggregate counters for a fuzz run or corpus replay.
#[derive(Debug, Clone, Default)]
pub struct FuzzStats {
    pub cases: usize,
    pub crashes: usize,
    /// Cases whose container prelude parsed completely — the coverage
    /// proxy: these reached layer/chunk handling.
    pub survived_prefix: usize,
    /// Cases the target fully accepted (parsed Ok end to end).
    pub accepted: usize,
    /// Whether allocation budgets were actually enforced.
    pub alloc_metered: bool,
}

impl FuzzStats {
    /// Fraction of cases that survived into layer/chunk handling.
    pub fn survival_ratio(&self) -> f64 {
        if self.cases == 0 {
            return 0.0;
        }
        self.survived_prefix as f64 / self.cases as f64
    }

    fn absorb_case(&mut self, outcome: &CaseOutcome) {
        self.cases += 1;
        if outcome.survived_prefix {
            self.survived_prefix += 1;
        }
        if outcome.accepted {
            self.accepted += 1;
        }
    }
}

#[cfg(test)]
const SELFTEST_PANIC_MARKER: &[u8] = b"__fuzz_selftest_panic__";

#[derive(Debug, Clone, Copy, Default)]
pub(crate) struct CaseOutcome {
    pub(crate) survived_prefix: bool,
    pub(crate) accepted: bool,
}

// ---------------------------------------------------------------------------
// Panic-hook quieting
//
// catch_unwind still runs the global panic hook, which would spray a
// backtrace per crasher. A process-wide hook installed once defers to
// the previous hook unless the current thread is inside a fuzz case.
// ---------------------------------------------------------------------------

thread_local! {
    static QUIET: Cell<bool> = const { Cell::new(false) };
}

fn install_quiet_hook() {
    static ONCE: std::sync::Once = std::sync::Once::new();
    ONCE.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            let quiet = QUIET.try_with(|q| q.get()).unwrap_or(false);
            if !quiet {
                prev(info);
            }
        }));
    });
}

/// RAII guard: panics on this thread are expected (and silenced) while
/// it lives. Other threads' panics keep their normal reporting.
pub(crate) struct Quiet;

impl Quiet {
    pub(crate) fn new() -> Self {
        install_quiet_hook();
        QUIET.with(|q| q.set(true));
        Quiet
    }
}

impl Drop for Quiet {
    fn drop(&mut self) {
        let _ = QUIET.try_with(|q| q.set(false));
    }
}

// ---------------------------------------------------------------------------
// Targets
// ---------------------------------------------------------------------------

fn exec(target: TargetKind, input: &[u8]) -> CaseOutcome {
    // unit-test tripwire: gives the catch/minimize machinery a
    // deterministic crasher without planting a real bug in any parser
    #[cfg(test)]
    if input.ends_with(SELFTEST_PANIC_MARKER) {
        panic!("selftest panic");
    }
    match target {
        TargetKind::Container => exec_container(input),
        TargetKind::Stream => exec_stream(input),
        TargetKind::Http => exec_http(input),
        TargetKind::Range => exec_range(input),
        TargetKind::Encoder => exec_encoder(input),
        TargetKind::DeltaApply => exec_delta_apply(input),
    }
}

/// The mutated-parent apply target: split the framed input into parent
/// container bytes and delta segment bytes, parse both, and push them
/// through [`crate::delta::apply`]. The parent half was usually mutated
/// *after* the delta was encoded against it, so the fingerprint check
/// is the boundary under test: apply must reject with a structured
/// error, or — when the mutation canonicalizes away (or the pair is
/// pristine) — produce a container that is canonical on the wire and
/// identical to what the streaming applier reconstructs.
fn exec_delta_apply(input: &[u8]) -> CaseOutcome {
    let (parent_bytes, delta_bytes) = gen::split_delta_pair(input);
    let parent = CompressedModel::deserialize(parent_bytes);
    let delta = DeltaModel::deserialize(delta_bytes);
    let (Ok(parent), Ok(delta)) = (parent, delta) else {
        // a mutated parent (or delta) may simply be unparseable — the
        // structured parse error is the rejection
        return CaseOutcome::default();
    };
    // both halves parsed: this case reached the apply trust boundary
    let survived_prefix = true;
    let Ok(applied) = crate::delta::apply(&parent, &delta, 1) else {
        return CaseOutcome { survived_prefix, accepted: false };
    };
    // byte-sane, part 1: the output is a canonical container
    let y = applied.serialize();
    let m2 = CompressedModel::deserialize(&y)
        .unwrap_or_else(|e| panic!("applied container rejected by its own parser: {e}"));
    assert_eq!(m2.serialize(), y, "delta apply output is not canonical");
    // byte-sane, part 2: batch-accept ⇒ stream-accept, with identical
    // reconstructed levels (both sides ran the same fingerprint check
    // against the same parent, so they must agree)
    let mut sa = crate::delta::StreamApplier::new(&parent, 1);
    let streamed = sa
        .feed(delta_bytes)
        .and_then(|ls| {
            sa.finish()?;
            Ok(ls)
        })
        .unwrap_or_else(|e| panic!("batch apply accepted but stream apply rejected: {e}"));
    assert_eq!(streamed.len(), applied.layers.len());
    for (sl, bl) in streamed.iter().zip(&applied.layers) {
        assert_eq!(
            sl.levels,
            bl.decode_levels_with(1),
            "stream apply diverged from batch apply on layer {:?}",
            bl.name
        );
    }
    CaseOutcome { survived_prefix, accepted: true }
}

fn exec_container(input: &[u8]) -> CaseOutcome {
    let survived_prefix = matches!(parse_container_prefix(input), Ok(Parsed::Complete(..)));
    if input.len() > 4 && input[4] == VERSION_DELTA {
        return exec_delta_container(input, survived_prefix);
    }
    if input.len() > 4 && input[4] == VERSION_PROGRESSIVE {
        return exec_progressive_container(input, survived_prefix);
    }
    let Ok(m) = CompressedModel::deserialize(input) else {
        return CaseOutcome { survived_prefix, accepted: false };
    };
    // accepted input ⇒ reencode must be accepted and be a serialization
    // fixpoint (x itself may differ from y: v2 single-chunk forms
    // canonicalize, so idempotence — not x == y — is the invariant)
    let y = m.serialize();
    let m2 = CompressedModel::deserialize(&y)
        .unwrap_or_else(|e| panic!("reencode of accepted container rejected: {e}"));
    assert_eq!(m2.serialize(), y, "serialize∘deserialize is not idempotent");
    for l in &m.layers {
        let levels = l.decode_levels_with(1);
        assert_eq!(
            levels.len(),
            l.n_weights,
            "layer {:?} decoded {} levels, header claims {}",
            l.name,
            levels.len(),
            l.n_weights
        );
    }
    // batch-accept ⇒ stream-accept: both sides share the prefix parsers
    if let Err(e) = crate::serve::stream::decode_all(input) {
        panic!("batch accepted but stream decoder rejected: {e}");
    }
    CaseOutcome { survived_prefix, accepted: true }
}

/// The v3 arm of [`exec_container`]: same idempotence/decode-count/
/// stream-differential invariants, on [`DeltaModel`].
fn exec_delta_container(input: &[u8], survived_prefix: bool) -> CaseOutcome {
    let Ok(dm) = DeltaModel::deserialize(input) else {
        return CaseOutcome { survived_prefix, accepted: false };
    };
    let y = dm.serialize();
    let dm2 = DeltaModel::deserialize(&y)
        .unwrap_or_else(|e| panic!("reencode of accepted delta segment rejected: {e}"));
    assert_eq!(dm2.serialize(), y, "v3 serialize∘deserialize is not idempotent");
    for l in &dm.layers {
        if let crate::model::DeltaLayer::Coded(cl) = l {
            let levels = cl.decode_levels_with(1);
            assert_eq!(
                levels.len(),
                cl.n_weights,
                "delta layer {:?} decoded {} residuals, header claims {}",
                cl.name,
                levels.len(),
                cl.n_weights
            );
        }
    }
    // batch-accept ⇒ stream-accept holds for delta segments too
    if let Err(e) = crate::serve::stream::decode_all(input) {
        panic!("batch accepted v3 but stream decoder rejected: {e}");
    }
    CaseOutcome { survived_prefix, accepted: true }
}

/// The v4 arm of [`exec_container`]: idempotence and decode-count on
/// every tier's records, plus two differentials — the streaming
/// decoder must accept whatever batch accepts, and the tier-by-tier
/// [`crate::delta::ProgressiveApplier`] must reconstruct exactly what
/// batch [`crate::delta::materialize`] produces at the final tier.
///
/// Note the truncation rule: an accepted v4 input may be a strict tier
/// prefix of the file that was mutated, so `serialize()` may legally
/// shrink the tier table (canonicalization). Idempotence on the
/// *reencoded* bytes — not `x == y` — is the invariant, same as the v2
/// single-chunk canonical form.
fn exec_progressive_container(input: &[u8], survived_prefix: bool) -> CaseOutcome {
    let Ok(pm) = ProgressiveModel::deserialize(input) else {
        return CaseOutcome { survived_prefix, accepted: false };
    };
    let y = pm.serialize();
    let pm2 = ProgressiveModel::deserialize(&y)
        .unwrap_or_else(|e| panic!("reencode of accepted progressive container rejected: {e}"));
    assert_eq!(pm2.serialize(), y, "v4 serialize∘deserialize is not idempotent");
    for l in &pm.base {
        let levels = l.decode_levels_with(1);
        assert_eq!(
            levels.len(),
            l.n_weights,
            "base layer {:?} decoded {} levels, header claims {}",
            l.name,
            levels.len(),
            l.n_weights
        );
    }
    for tier in &pm.refinements {
        for l in tier {
            if let crate::model::DeltaLayer::Coded(cl) = l {
                let levels = cl.decode_levels_with(1);
                assert_eq!(
                    levels.len(),
                    cl.n_weights,
                    "refinement layer {:?} decoded {} residuals, header claims {}",
                    cl.name,
                    levels.len(),
                    cl.n_weights
                );
            }
        }
    }
    // batch-accept ⇒ stream-accept holds for progressive containers too
    if let Err(e) = crate::serve::stream::decode_all(input) {
        panic!("batch accepted v4 but stream decoder rejected: {e}");
    }
    // batch materialize vs streaming tier applier: both total on
    // accepted *syntax*, and when the residual algebra is applicable
    // they must agree; a semantic mismatch (e.g. a refinement layer
    // renamed by mutation) must error on both sides, never panic.
    let batch_final = crate::delta::materialize(&pm, pm.n_tiers() - 1, 1);
    let mut applier = crate::delta::ProgressiveApplier::new(1);
    let streamed = applier.feed(&y).and_then(|snaps| {
        applier.finish()?;
        Ok(snaps)
    });
    match (batch_final, streamed) {
        (Ok(full), Ok(snaps)) => {
            let last = snaps.last().expect("accepted container has ≥1 tier");
            assert_eq!(last.tier + 1, pm.n_tiers());
            assert_eq!(last.layers.len(), full.layers.len());
            for (sl, wl) in last.layers.iter().zip(&full.layers) {
                assert_eq!(
                    sl.levels,
                    wl.decode_levels_with(1),
                    "streamed tier diverged from batch materialize on {:?}",
                    wl.name
                );
            }
        }
        (Err(_), _) | (_, Err(_)) => {} // structured rejection is fine
    }
    CaseOutcome { survived_prefix, accepted: true }
}

/// The encoder-side target: the input bytes are a recipe for a hostile
/// (parent, target) model pair. The parent must survive the standard
/// pipeline (its values are finite, if nasty); the delta encoder must
/// either reject the target with a structured error (NaN/Inf) or
/// produce a delta that applies back to the full target container
/// byte-for-byte and round-trips on the wire.
fn exec_encoder(input: &[u8]) -> CaseOutcome {
    let (parent_model, target_model) = gen::hostile_model_pair(input);
    let spec = CompressionSpec {
        chunks: 1 + (input.first().copied().unwrap_or(0) % 3) as u32,
        ..CompressionSpec::default()
    };
    let (parent, _rep) = compress_model(&parent_model, &spec, 1);
    match crate::delta::encode_from_model(&parent, &target_model, &spec, 1) {
        Err(_) => CaseOutcome { survived_prefix: true, accepted: false },
        Ok((full, dm, _report)) => {
            let applied = crate::delta::apply(&parent, &dm, 1)
                .unwrap_or_else(|e| panic!("encoder produced an unappliable delta: {e}"));
            assert_eq!(
                applied.serialize(),
                full.serialize(),
                "delta apply diverged from the target container"
            );
            let bytes = dm.serialize();
            let dm2 = DeltaModel::deserialize(&bytes)
                .unwrap_or_else(|e| panic!("encoder emitted an unparseable delta segment: {e}"));
            assert_eq!(dm2.serialize(), bytes, "emitted delta segment is not canonical");
            CaseOutcome { survived_prefix: true, accepted: true }
        }
    }
}

fn exec_stream(input: &[u8]) -> CaseOutcome {
    let survived_prefix = matches!(parse_container_prefix(input), Ok(Parsed::Complete(..)));
    // split sizes derived from the input so replays are deterministic
    let mut rng = SplitMix64::new(fnv1a(input) | 1);
    let mut dec = StreamDecoder::new();
    let mut pos = 0usize;
    let mut failed = false;
    while pos < input.len() {
        let n = 1 + rng.below(63) as usize;
        let end = (pos + n).min(input.len());
        if dec.feed(&input[pos..end]).is_err() {
            failed = true;
            break;
        }
        pos = end;
    }
    let accepted = !failed && dec.finish().is_ok();
    CaseOutcome { survived_prefix, accepted }
}

fn exec_http(input: &[u8]) -> CaseOutcome {
    let Ok(req) = parse_request_head(input) else {
        return CaseOutcome { survived_prefix: false, accepted: false };
    };
    let _ = req.header("host");
    let _ = req.header("range");
    for len in [0usize, 1, 100, 1 << 20, usize::MAX >> 1] {
        let _ = req.byte_range(len);
    }
    CaseOutcome { survived_prefix: true, accepted: true }
}

fn exec_range(input: &[u8]) -> CaseOutcome {
    let value = String::from_utf8_lossy(input);
    // evaluate through a real Request so header plumbing is included
    let head = format!("GET / HTTP/1.1\r\nRange: {value}\r\n");
    let Ok(req) = parse_request_head(head.as_bytes()) else {
        return CaseOutcome { survived_prefix: true, accepted: false };
    };
    for len in [0usize, 1, 99, 100, 1 << 20, usize::MAX >> 1] {
        if let crate::serve::http::RangeOutcome::Satisfiable(r) = req.byte_range(len) {
            assert!(r.start < r.end && r.end <= len, "range {r:?} outside body of {len}");
        }
    }
    CaseOutcome { survived_prefix: true, accepted: true }
}

// ---------------------------------------------------------------------------
// Case runner + minimizer
// ---------------------------------------------------------------------------

fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Run one input against one target; `None` means every invariant held.
pub(crate) fn run_case(
    target: TargetKind,
    input: &[u8],
    budgets: &Budgets,
    metered: bool,
) -> (Option<CrashKind>, CaseOutcome) {
    alloc::reset();
    let t0 = Instant::now();
    let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| exec(target, input)));
    let elapsed = t0.elapsed().as_millis() as u64;
    let peak = alloc::peak();
    match res {
        Err(p) => (Some(CrashKind::Panic(panic_message(p))), CaseOutcome::default()),
        Ok(outcome) => {
            if metered && peak > budgets.alloc_bytes {
                (Some(CrashKind::AllocBudget(peak)), outcome)
            } else if elapsed > budgets.millis {
                (Some(CrashKind::TimeBudget(elapsed)), outcome)
            } else {
                (None, outcome)
            }
        }
    }
}

/// Run one input with per-case coverage capture: clears the thread's
/// edge map, runs the case, and returns the slots it hit (always empty
/// when the `fuzz-cov` feature is off).
pub(crate) fn run_case_cov(
    target: TargetKind,
    input: &[u8],
    budgets: &Budgets,
    metered: bool,
) -> (Option<CrashKind>, CaseOutcome, Vec<usize>) {
    super::cov::reset();
    let (crash, outcome) = run_case(target, input, budgets, metered);
    (crash, outcome, super::cov::hot_slots())
}

/// Deterministic ddmin-style chunk removal over an arbitrary predicate:
/// repeatedly delete byte chunks (halving the chunk size) while `holds`
/// stays true, bounded by `max_attempts` probes so minimization can
/// never become the hang.
///
/// The caller vouches that `holds(input)` is true — the unmodified
/// input is never re-probed (the fuzz loops only minimize inputs that
/// just crashed, so re-running the predicate on them wastes a probe and
/// re-fires flaky crashers for nothing).
///
/// The allocation meter is reset before every probe, so predicates
/// keyed on [`alloc::peak`] — alloc-budget crashers, coverage-preserving
/// re-minimization under metering — judge each candidate in isolation
/// instead of inheriting the peak of whatever probe ran before it.
pub fn ddmin(
    input: &[u8],
    mut holds: impl FnMut(&[u8]) -> bool,
    max_attempts: usize,
) -> Vec<u8> {
    let mut probe = |buf: &[u8]| {
        alloc::reset();
        holds(buf)
    };
    let mut cur = input.to_vec();
    let mut attempts = 0usize;
    let mut chunk = (cur.len() / 2).max(1);
    loop {
        let mut progress = false;
        let mut start = 0usize;
        while start < cur.len() && attempts < max_attempts {
            let end = (start + chunk).min(cur.len());
            let mut cand = Vec::with_capacity(cur.len() - (end - start));
            cand.extend_from_slice(&cur[..start]);
            cand.extend_from_slice(&cur[end..]);
            attempts += 1;
            if probe(&cand) {
                cur = cand;
                progress = true;
            } else {
                start = end;
            }
        }
        if attempts >= max_attempts {
            break;
        }
        if !progress {
            if chunk == 1 {
                break;
            }
            chunk /= 2;
        }
    }
    cur
}

/// Shrink a known-crashing input with [`ddmin`] under the "still
/// crashes" predicate. `input` must crash (callers have just observed
/// the crash); a flaky input simply fails to shrink and comes back
/// unchanged.
pub fn minimize(target: TargetKind, input: &[u8], budgets: &Budgets, metered: bool) -> Vec<u8> {
    ddmin(input, |buf| run_case(target, buf, budgets, metered).0.is_some(), 4000)
}

// ---------------------------------------------------------------------------
// Fuzz loops + corpus replay
// ---------------------------------------------------------------------------

pub(crate) fn make_input(target: TargetKind, rng: &mut SplitMix64) -> Vec<u8> {
    // 1-in-8 cases run unmutated: keeps the accept/roundtrip invariants
    // exercised and anchors the survival baseline
    let pristine = rng.below(8) == 0;
    match target {
        TargetKind::Container | TargetKind::Stream => {
            // 1-in-4 cases work a v3 delta segment, 1-in-4 a v4
            // progressive container — same field-mapped mutation
            // machinery either way
            let base = match rng.below(8) {
                0 | 1 => gen::delta_container(rng),
                2 | 3 => gen::progressive_container(rng),
                _ => gen::container(rng),
            };
            if pristine {
                return base;
            }
            match gen::map_fields(&base) {
                Ok(fields) => mutate::container(&base, &fields, rng),
                Err(_) => base,
            }
        }
        TargetKind::Encoder => {
            // the input *is* the hostile-model recipe; every byte string
            // is a valid recipe, so mutation is plain byte noise
            (0..rng.below(700)).map(|_| rng.next_u64() as u8).collect()
        }
        TargetKind::Http => {
            let base = gen::http_request(rng);
            if pristine {
                base
            } else {
                mutate::http(&base, rng)
            }
        }
        TargetKind::Range => {
            let base = gen::range_value(rng);
            if pristine { base } else { mutate::range(&base, rng) }.into_bytes()
        }
        TargetKind::DeltaApply => {
            // the generator owns the post-fingerprint parent mutation
            // (pristine pairs are its 1-in-8 arm)
            gen::delta_apply_pair(rng)
        }
    }
}

/// Generate-mutate-run `cases` inputs against `target`. Crashers are
/// minimized before being returned.
pub fn fuzz_target(
    target: TargetKind,
    cases: usize,
    seed: u64,
    budgets: &Budgets,
) -> (FuzzStats, Vec<Crash>) {
    let _quiet = Quiet::new();
    let metered = alloc::probe();
    let mut rng = SplitMix64::new(seed ^ fnv1a(target.as_str().as_bytes()));
    let mut stats = FuzzStats { alloc_metered: metered, ..Default::default() };
    let mut crashes = Vec::new();
    for _ in 0..cases {
        let input = make_input(target, &mut rng);
        let (crash, outcome) = run_case(target, &input, budgets, metered);
        stats.absorb_case(&outcome);
        if let Some(kind) = crash {
            stats.crashes += 1;
            let input = minimize(target, &input, budgets, metered);
            crashes.push(Crash { target, kind, input });
        }
    }
    (stats, crashes)
}

/// Corpus subdirectory → fuzz-target mapping shared by
/// [`replay_corpus`], the evolve loop's corpus loader and the
/// coverage-floor test. Container corpus files (v1/v2, v3 delta
/// segments *and* v4 progressive containers) run against **both** the
/// batch and the stream targets.
pub fn corpus_groups() -> [(&'static str, &'static [TargetKind]); 5] {
    [
        ("container", &[TargetKind::Container, TargetKind::Stream]),
        ("http", &[TargetKind::Http]),
        ("range", &[TargetKind::Range]),
        ("encoder", &[TargetKind::Encoder]),
        ("delta_apply", &[TargetKind::DeltaApply]),
    ]
}

/// Replay the checked-in corpus at `root` (`container/`, `http/`,
/// `range/`, `encoder/`, `delta_apply/` subdirectories; missing ones
/// are skipped). Filename conventions: `accept_*` must parse Ok,
/// `reject_*` must parse Err, anything else only has to uphold the
/// crash invariants. Container corpus files run against both the batch
/// and the stream targets; `encoder/` files are hostile-model recipes;
/// `delta_apply/` files are framed (parent, delta) pairs.
pub fn replay_corpus(root: &Path, budgets: &Budgets) -> Result<(FuzzStats, Vec<Crash>)> {
    let _quiet = Quiet::new();
    let metered = alloc::probe();
    let mut stats = FuzzStats { alloc_metered: metered, ..Default::default() };
    let mut crashes = Vec::new();
    for (sub, targets) in corpus_groups() {
        let dir = root.join(sub);
        if !dir.is_dir() {
            continue;
        }
        let mut paths: Vec<_> = std::fs::read_dir(&dir)
            .with_context(|| format!("reading corpus dir {dir:?}"))?
            .filter_map(|e| e.ok().map(|e| e.path()))
            .filter(|p| p.is_file())
            .collect();
        paths.sort();
        for path in paths {
            let input =
                std::fs::read(&path).with_context(|| format!("reading corpus file {path:?}"))?;
            let name = path.file_name().and_then(|n| n.to_str()).unwrap_or("").to_string();
            let expect = if name.starts_with("accept_") {
                Some(true)
            } else if name.starts_with("reject_") {
                Some(false)
            } else {
                None
            };
            for &t in targets {
                let (crash, outcome) = run_case(t, &input, budgets, metered);
                stats.absorb_case(&outcome);
                if let Some(kind) = crash {
                    stats.crashes += 1;
                    crashes.push(Crash { target: t, kind, input: input.clone() });
                    continue;
                }
                if let Some(want) = expect {
                    if outcome.accepted != want {
                        stats.crashes += 1;
                        crashes.push(Crash {
                            target: t,
                            kind: CrashKind::Expectation(format!(
                                "{name} [{}]: expected accepted={want}, got accepted={}",
                                t.as_str(),
                                outcome.accepted
                            )),
                            input: input.clone(),
                        });
                    }
                }
            }
        }
    }
    Ok((stats, crashes))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn valid_containers_are_accepted_with_no_crashes() {
        let mut rng = SplitMix64::new(101);
        let budgets = Budgets::default();
        for _ in 0..8 {
            let bytes = gen::container(&mut rng);
            for t in [TargetKind::Container, TargetKind::Stream] {
                let (crash, outcome) = run_case(t, &bytes, &budgets, false);
                assert!(crash.is_none(), "{:?}: {:?}", t, crash);
                assert!(outcome.accepted && outcome.survived_prefix);
            }
        }
    }

    #[test]
    fn valid_delta_segments_are_accepted_with_no_crashes() {
        let mut rng = SplitMix64::new(103);
        let budgets = Budgets::default();
        for _ in 0..8 {
            let bytes = gen::delta_container(&mut rng);
            for t in [TargetKind::Container, TargetKind::Stream] {
                let (crash, outcome) = run_case(t, &bytes, &budgets, false);
                assert!(crash.is_none(), "{:?}: {:?}", t, crash);
                assert!(outcome.accepted && outcome.survived_prefix);
            }
        }
    }

    #[test]
    fn valid_progressive_containers_are_accepted_with_no_crashes() {
        let mut rng = SplitMix64::new(107);
        let budgets = Budgets::default();
        for _ in 0..8 {
            let bytes = gen::progressive_container(&mut rng);
            for t in [TargetKind::Container, TargetKind::Stream] {
                let (crash, outcome) = run_case(t, &bytes, &budgets, false);
                assert!(crash.is_none(), "{:?}: {:?}", t, crash);
                assert!(outcome.accepted && outcome.survived_prefix);
            }
        }
    }

    #[test]
    fn encoder_target_rejects_nonfinite_without_crashing() {
        let budgets = Budgets::default();
        // craft a recipe whose target re-draws land on NaN/±Inf: layer
        // count, size arm 2, size byte 2 (→ 3 weights), then (parent,
        // target, sigma) selector triples whose target byte ≡ 0 mod 4
        // forces a re-draw from HOSTILE_ANY at indices 12/13/14
        let mut input = vec![1u8, 2, 2];
        for sel in [48u8, 52, 56] {
            input.extend_from_slice(&[6, sel, 8]);
        }
        input.push(0); // no bias
        let (crash, outcome) = run_case(TargetKind::Encoder, &input, &budgets, false);
        assert!(crash.is_none(), "non-finite target must not crash: {crash:?}");
        assert!(!outcome.accepted, "non-finite target must be rejected");
        // and an all-finite recipe must be accepted (encode + apply +
        // wire round-trip all verified inside exec_encoder)
        let finite = [2u8, 3, 9, 1, 8, 10, 2, 8, 4, 3, 8, 1, 5, 1, 8, 2, 0, 1];
        let (crash, outcome) = run_case(TargetKind::Encoder, &finite, &budgets, false);
        assert!(crash.is_none(), "finite hostile recipe crashed: {crash:?}");
        assert!(outcome.accepted, "finite hostile recipe must delta-encode");
    }

    #[test]
    fn short_fuzz_runs_are_deterministic_and_clean() {
        for t in TargetKind::all() {
            let b = Budgets::default();
            let (s1, c1) = fuzz_target(t, 40, 7, &b);
            let (s2, c2) = fuzz_target(t, 40, 7, &b);
            assert_eq!(s1.cases, 40);
            assert_eq!(s1.crashes, c1.len());
            // determinism: same seed, same outcome
            assert_eq!(s1.crashes, s2.crashes);
            assert_eq!(s1.survived_prefix, s2.survived_prefix);
            assert_eq!(s1.accepted, s2.accepted);
            assert_eq!(c1.len(), c2.len());
            assert!(
                c1.is_empty(),
                "{}: unexpected crasher: {} ({} bytes)",
                t.as_str(),
                c1[0].kind,
                c1[0].input.len()
            );
        }
    }

    #[test]
    fn pristine_delta_apply_pairs_are_accepted_with_no_crashes() {
        // 1-in-8 generated pairs keep the parent pristine, so a fixed
        // seed sweep must find accepted cases; every case (mutated or
        // not) must uphold the crash invariants
        let mut rng = SplitMix64::new(109);
        let budgets = Budgets::default();
        let mut accepted = 0usize;
        for _ in 0..64 {
            let input = gen::delta_apply_pair(&mut rng);
            let (crash, outcome) = run_case(TargetKind::DeltaApply, &input, &budgets, false);
            assert!(crash.is_none(), "delta_apply crashed: {crash:?}");
            if outcome.accepted {
                accepted += 1;
                assert!(outcome.survived_prefix);
            }
        }
        assert!(accepted > 0, "no pristine pair applied cleanly in 64 draws");
    }

    #[test]
    fn delta_apply_rejects_truncated_and_lying_parents() {
        // hand-build a pristine pair, then break the parent three ways:
        // truncation, byte noise in the payload, and a version-byte lie.
        // All must come back as structured rejections, never crashes.
        let mut rng = SplitMix64::new(113);
        let budgets = Budgets::default();
        let (parent, delta) = gen::delta_apply_parts(&mut rng);
        let pristine = gen::frame_delta_pair(&parent, &delta);
        let (crash, outcome) = run_case(TargetKind::DeltaApply, &pristine, &budgets, false);
        assert!(crash.is_none(), "{crash:?}");
        assert!(outcome.accepted, "pristine pair must apply");

        let mut cases: Vec<Vec<u8>> = Vec::new();
        cases.push(gen::frame_delta_pair(&parent[..parent.len() / 2], &delta));
        let mut noisy = parent.clone();
        let mid = noisy.len() / 2;
        noisy[mid] ^= 0xFF;
        cases.push(gen::frame_delta_pair(&noisy, &delta));
        let mut vlie = parent.clone();
        vlie[4] = 9; // unsupported version
        cases.push(gen::frame_delta_pair(&vlie, &delta));
        for (i, input) in cases.iter().enumerate() {
            let (crash, outcome) = run_case(TargetKind::DeltaApply, input, &budgets, false);
            assert!(crash.is_none(), "mutated-parent case {i} crashed: {crash:?}");
            assert!(!outcome.accepted, "mutated-parent case {i} must not apply byte-noise");
        }
    }

    #[test]
    fn ddmin_never_reprobes_the_unmodified_input() {
        // the caller vouches for the input; every probe must be a strict
        // sub-input (the old minimize wasted a probe re-running it)
        let input = [1u8, 2, 3, 4];
        let mut probed_full = false;
        let min = ddmin(
            &input,
            |buf| {
                if buf == input {
                    probed_full = true;
                }
                buf.contains(&3)
            },
            4000,
        );
        assert_eq!(min, [3]);
        assert!(!probed_full, "ddmin re-probed the unmodified input");
    }

    #[test]
    fn panics_are_caught_and_minimized() {
        let b = Budgets::default();
        let mut input = vec![0xAAu8; 48];
        input.extend_from_slice(SELFTEST_PANIC_MARKER);
        let (crash, _) = run_case(TargetKind::Range, &input, &b, false);
        match crash {
            Some(CrashKind::Panic(msg)) => assert!(msg.contains("selftest"), "{msg}"),
            other => panic!("expected a caught panic, got {other:?}"),
        }
        // ddmin must strip every padding byte but keep the trigger
        let min = minimize(TargetKind::Range, &input, &b, false);
        assert_eq!(min, SELFTEST_PANIC_MARKER);
    }

    #[test]
    fn crash_kind_display_is_stable() {
        assert_eq!(CrashKind::Panic("x".into()).to_string(), "panic: x");
        assert_eq!(
            CrashKind::AllocBudget(10).to_string(),
            "alloc budget exceeded: peak 10 bytes"
        );
        assert_eq!(CrashKind::TimeBudget(3).to_string(), "time budget exceeded: 3 ms");
    }

    #[test]
    fn replay_missing_corpus_is_empty_ok() {
        let (stats, crashes) =
            replay_corpus(Path::new("/nonexistent/corpus"), &Budgets::default()).unwrap();
        assert_eq!(stats.cases, 0);
        assert!(crashes.is_empty());
    }
}
