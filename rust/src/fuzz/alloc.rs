//! Thread-local allocation metering for the fuzz driver's
//! never-allocate-beyond-budget invariant.
//!
//! [`CountingAlloc`] wraps the system allocator and keeps per-thread
//! live/peak byte counters. It is installed as the `#[global_allocator]`
//! by the binaries that want metering (the `deepcabac` CLI and the
//! `fuzz_structured` test binary) — the library itself never installs
//! it, so ordinary consumers pay nothing. The driver calls [`probe`]
//! once per thread to discover whether metering is live and only
//! enforces allocation budgets when it is.
//!
//! The counters are `const`-initialized `Cell`s: no lazy initialization
//! (which would allocate from inside `alloc` and recurse) and no `Drop`
//! (so access during TLS teardown cannot abort).

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

thread_local! {
    static LIVE: Cell<usize> = const { Cell::new(0) };
    static PEAK: Cell<usize> = const { Cell::new(0) };
}

/// System allocator wrapper that tracks per-thread live and peak bytes.
pub struct CountingAlloc;

#[inline]
fn on_alloc(size: usize) {
    // TLS access can fail during thread teardown; losing those few
    // bookkeeping bytes is fine, aborting the process is not
    let _ = LIVE.try_with(|l| {
        let live = l.get().saturating_add(size);
        l.set(live);
        let _ = PEAK.try_with(|p| {
            if live > p.get() {
                p.set(live);
            }
        });
    });
}

#[inline]
fn on_dealloc(size: usize) {
    let _ = LIVE.try_with(|l| l.set(l.get().saturating_sub(size)));
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let p = System.alloc(layout);
        if !p.is_null() {
            on_alloc(layout.size());
        }
        p
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout);
        on_dealloc(layout.size());
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        let p = System.realloc(ptr, layout, new_size);
        if !p.is_null() {
            on_dealloc(layout.size());
            on_alloc(new_size);
        }
        p
    }
}

/// Reset this thread's live/peak counters (start of a fuzz case).
pub fn reset() {
    let _ = LIVE.try_with(|l| l.set(0));
    let _ = PEAK.try_with(|p| p.set(0));
}

/// Peak live bytes allocated on this thread since the last [`reset`].
pub fn peak() -> usize {
    PEAK.try_with(|p| p.get()).unwrap_or(0)
}

/// True when [`CountingAlloc`] is the active global allocator: a probe
/// allocation must move the meter. Called once per fuzzing thread; when
/// false, allocation budgets are reported as unmetered instead of
/// silently "passing".
pub fn probe() -> bool {
    reset();
    let v = std::hint::black_box(vec![0u8; 4096]);
    let metered = peak() >= 4096;
    drop(v);
    reset();
    metered
}

#[cfg(test)]
mod tests {
    // Unit tests in the library binary do NOT install the allocator, so
    // all that can be asserted here is the unmetered behavior; the
    // metered path is exercised by tests/fuzz_structured.rs, which does
    // install it.
    #[test]
    fn unmetered_probe_is_false_and_peak_zero() {
        assert!(!super::probe());
        let _v = vec![0u8; 8192];
        assert_eq!(super::peak(), 0);
    }
}
