//! AFL-style corpus evolution on top of the edge-counter shim
//! ([`super::cov`]).
//!
//! The loop keeps a pool of *seeds* (the on-disk corpus plus every
//! promoted find), schedules them by **energy** — the rarity of the
//! edges a seed reaches, `Σ 1/freq[slot]` over its edge set, so inputs
//! that alone exercise an obscure parser path get mutated more often —
//! and promotes any mutant that lights up a never-seen edge slot.
//! Promoted finds are periodically re-minimized with a
//! coverage-preserving [`super::driver::ddmin`] predicate (the shrunk
//! input must still hit every slot the find was promoted for, without
//! crashing), so the corpus stays small enough to replay in CI.
//!
//! Everything is deterministic under a fixed seed *and* a fixed case
//! count: the RNG is `SplitMix64` salted per target, scheduling breaks
//! ties by index, and no wall-clock feeds back into decisions — the
//! optional `max_millis` cap only decides where the loop *stops*, so a
//! time-capped run is a prefix of the uncapped one.
//!
//! Without the `fuzz-cov` feature every edge set is empty: scheduling
//! degrades to uniform, nothing is ever promoted, and the loop becomes
//! a plain seed-mutating fuzzer — still useful, still deterministic.

use std::collections::{BTreeMap, BTreeSet};
use std::path::Path;
use std::time::Instant;

use anyhow::{Context, Result};

use super::driver::{
    self, corpus_groups, make_input, run_case_cov, Budgets, Crash, TargetKind,
};
use super::{alloc, cov, gen, mutate};
use crate::util::{fnv1a, SplitMix64};

/// Extra RNG salt so an evolved run never replays the exact generation
/// sequence of the fixed-seed batch loop it is compared against.
const EVOLVE_SALT: u64 = 0xE501_F0E5_ED0C_AB0C;

/// Knobs for one [`evolve_target`] run.
#[derive(Debug, Clone, Copy)]
pub struct EvolveCfg {
    /// Base RNG seed (salted per target, like the batch loop).
    pub seed: u64,
    /// Mutant executions to perform (re-minimization probes and the
    /// initial corpus replay are not counted against this).
    pub cases: usize,
    /// Wall-clock cap in milliseconds; `0` means no cap. The cap only
    /// stops the loop early — it never alters scheduling, so a capped
    /// run is a prefix of the uncapped run with the same seed.
    pub max_millis: u64,
    /// Per-case resource budgets (same invariants as the batch loop).
    pub budgets: Budgets,
    /// Re-minimize one not-yet-shrunk promoted find every this many
    /// executions; `0` disables re-minimization.
    pub reminimize_every: usize,
}

impl Default for EvolveCfg {
    fn default() -> Self {
        Self {
            seed: 42,
            cases: 2000,
            max_millis: 0,
            budgets: Budgets::default(),
            reminimize_every: 256,
        }
    }
}

/// What one [`evolve_target`] run did — the per-target record behind
/// `BENCH_fuzz.json`.
#[derive(Debug, Clone)]
pub struct EvolveReport {
    pub target: TargetKind,
    /// Mutant executions actually performed (≤ `cfg.cases`; smaller only
    /// when the `max_millis` cap fired).
    pub cases: usize,
    /// Unique edge slots hit across the whole run (0 without `fuzz-cov`).
    pub unique_edges: usize,
    /// Final seed-pool size (initial corpus + promoted finds).
    pub corpus_len: usize,
    /// Mutants promoted for reaching a never-seen edge.
    pub promoted: usize,
    /// Invariant violations found (inputs minimized).
    pub crashes: Vec<Crash>,
    /// Edge-discovery curve: `(execution index, cumulative unique
    /// edges)` at every promotion, plus a final point at the end of the
    /// run. Execution index 0 is the initial corpus replay.
    pub discovery: Vec<(usize, usize)>,
    /// The promoted (and possibly re-minimized) inputs, in promotion
    /// order — the corpus growth to check in / upload.
    pub promoted_inputs: Vec<Vec<u8>>,
    pub elapsed_ms: u64,
    pub execs_per_sec: f64,
    pub alloc_metered: bool,
    pub cov_enabled: bool,
}

/// One scheduled corpus entry.
struct Seed {
    input: Vec<u8>,
    /// Every edge slot this input hits.
    edges: Vec<usize>,
    /// The never-before-seen slots this input was promoted for (empty
    /// for initial-corpus seeds) — the set its re-minimization preserves.
    novel: BTreeSet<usize>,
    minimized: bool,
}

/// Rarity-weighted energies for the current pool: seed *i* gets
/// `BASE + Σ 1/freq[slot]` over its edges, where `freq[slot]` counts
/// pool members hitting that slot. The constant base keeps zero-edge
/// seeds (and the whole pool when `fuzz-cov` is off) schedulable.
fn energies(pool: &[Seed]) -> Vec<f64> {
    const BASE: f64 = 0.05;
    let mut freq: BTreeMap<usize, usize> = BTreeMap::new();
    for s in pool {
        for &e in &s.edges {
            *freq.entry(e).or_insert(0) += 1;
        }
    }
    pool.iter()
        .map(|s| BASE + s.edges.iter().map(|e| 1.0 / freq[e] as f64).sum::<f64>())
        .collect()
}

/// Deterministic weighted pick: first index whose cumulative energy
/// passes `x · total`.
fn pick_weighted(energy: &[f64], rng: &mut SplitMix64) -> usize {
    let total: f64 = energy.iter().sum();
    let mut x = rng.next_f64() * total;
    for (i, &e) in energy.iter().enumerate() {
        x -= e;
        if x <= 0.0 {
            return i;
        }
    }
    energy.len() - 1
}

/// Generic byte havoc for inputs with no field map (encoder recipes,
/// containers the walker rejects): flips, rewrites, truncation, inserts.
fn havoc(input: &[u8], rng: &mut SplitMix64) -> Vec<u8> {
    let mut out = input.to_vec();
    if out.is_empty() {
        return (0..1 + rng.below(16)).map(|_| rng.next_u64() as u8).collect();
    }
    let ops = 1 + rng.below(4);
    for _ in 0..ops {
        if out.is_empty() {
            out.push(rng.next_u64() as u8);
        }
        match rng.below(4) {
            0 => {
                let i = rng.below(out.len() as u64) as usize;
                out[i] ^= 1 << rng.below(8);
            }
            1 => {
                let i = rng.below(out.len() as u64) as usize;
                out[i] = rng.next_u64() as u8;
            }
            2 => {
                let keep = rng.below(out.len() as u64 + 1) as usize;
                out.truncate(keep);
            }
            _ => {
                let i = rng.below(out.len() as u64 + 1) as usize;
                out.insert(i, rng.next_u64() as u8);
            }
        }
    }
    out
}

/// Mutate a scheduled seed with the target's structure-aware operators
/// (falling back to [`havoc`] when the input no longer field-maps).
fn mutate_seed(target: TargetKind, input: &[u8], rng: &mut SplitMix64) -> Vec<u8> {
    match target {
        TargetKind::Container | TargetKind::Stream => match gen::map_fields(input) {
            Ok(fields) => mutate::container(input, &fields, rng),
            Err(_) => havoc(input, rng),
        },
        TargetKind::Http => mutate::http(input, rng),
        TargetKind::Range => {
            let s = String::from_utf8_lossy(input).into_owned();
            mutate::range(&s, rng).into_bytes()
        }
        TargetKind::Encoder => havoc(input, rng),
        TargetKind::DeltaApply => {
            // frame-aware: split the pair, mutate one side (field-aware
            // when it still maps), reframe — so the length prefix stays
            // coherent and mutants keep reaching the apply logic
            let (parent, delta) = gen::split_delta_pair(input);
            if rng.below(4) == 0 {
                let nd = match gen::map_fields(delta) {
                    Ok(fields) => mutate::container(delta, &fields, rng),
                    Err(_) => havoc(delta, rng),
                };
                gen::frame_delta_pair(parent, &nd)
            } else {
                let np = match gen::map_fields(parent) {
                    Ok(fields) => mutate::container(parent, &fields, rng),
                    Err(_) => havoc(parent, rng),
                };
                gen::frame_delta_pair(&np, delta)
            }
        }
    }
}

/// Evolve a corpus against one target. `initial` seeds the pool (the
/// on-disk corpus, typically — including all the hand-built reject
/// cases the generators rarely produce); when empty, a few generated
/// inputs bootstrap it so the loop always has something to schedule.
pub fn evolve_target(target: TargetKind, cfg: &EvolveCfg, initial: &[Vec<u8>]) -> EvolveReport {
    let _quiet = driver::Quiet::new();
    let metered = alloc::probe();
    let mut rng =
        SplitMix64::new(cfg.seed ^ fnv1a(target.as_str().as_bytes()) ^ EVOLVE_SALT);
    let t0 = Instant::now();

    let mut seen: BTreeSet<usize> = BTreeSet::new();
    let mut pool: Vec<Seed> = Vec::new();
    let mut crashes: Vec<Crash> = Vec::new();
    let mut discovery: Vec<(usize, usize)> = Vec::new();

    let mut bootstrap: Vec<Vec<u8>> = Vec::new();
    if initial.is_empty() {
        for _ in 0..4 {
            bootstrap.push(make_input(target, &mut rng));
        }
    }
    for input in initial.iter().chain(&bootstrap) {
        let (crash, _outcome, edges) = run_case_cov(target, input, &cfg.budgets, metered);
        if let Some(kind) = crash {
            // the checked-in corpus replays clean by invariant; a crash
            // here is a real regression — report it, don't schedule it
            let input = driver::minimize(target, input, &cfg.budgets, metered);
            crashes.push(Crash { target, kind, input });
            continue;
        }
        seen.extend(edges.iter().copied());
        pool.push(Seed { input: input.clone(), edges, novel: BTreeSet::new(), minimized: true });
    }
    discovery.push((0, seen.len()));

    let mut energy = energies(&pool);
    let mut executed = 0usize;
    let mut promoted = 0usize;
    while executed < cfg.cases {
        if cfg.max_millis > 0 && t0.elapsed().as_millis() as u64 >= cfg.max_millis {
            break;
        }
        // 1-in-16 executions inject a fresh generated input instead of
        // mutating a seed, so the pool never inbreeds (and an empty pool
        // — every initial seed crashed — always generates)
        let mutant = if pool.is_empty() || rng.below(16) == 0 {
            make_input(target, &mut rng)
        } else {
            let i = pick_weighted(&energy, &mut rng);
            mutate_seed(target, &pool[i].input, &mut rng)
        };
        executed += 1;
        let (crash, _outcome, edges) = run_case_cov(target, &mutant, &cfg.budgets, metered);
        if let Some(kind) = crash {
            let input = driver::minimize(target, &mutant, &cfg.budgets, metered);
            crashes.push(Crash { target, kind, input });
            continue;
        }
        let novel: BTreeSet<usize> =
            edges.iter().copied().filter(|e| !seen.contains(e)).collect();
        if !novel.is_empty() {
            seen.extend(novel.iter().copied());
            pool.push(Seed { input: mutant, edges, novel, minimized: false });
            promoted += 1;
            discovery.push((executed, seen.len()));
            energy = energies(&pool);
        }
        // periodic re-minimization: shrink one promoted find, keeping
        // its novel slots reachable and the input non-crashing
        if cfg.reminimize_every > 0 && executed % cfg.reminimize_every == 0 {
            if let Some(idx) = pool.iter().position(|s| !s.minimized) {
                let keep = pool[idx].novel.clone();
                let shrunk = driver::ddmin(
                    &pool[idx].input,
                    |buf| {
                        let (c, _o, slots) =
                            run_case_cov(target, buf, &cfg.budgets, metered);
                        c.is_none()
                            && keep.iter().all(|s| slots.binary_search(s).is_ok())
                    },
                    512,
                );
                let (_c, _o, edges) =
                    run_case_cov(target, &shrunk, &cfg.budgets, metered);
                let s = &mut pool[idx];
                s.input = shrunk;
                s.edges = edges;
                s.minimized = true;
                energy = energies(&pool);
            }
        }
    }

    let elapsed_ms = t0.elapsed().as_millis() as u64;
    discovery.push((executed, seen.len()));
    let promoted_inputs: Vec<Vec<u8>> =
        pool.iter().filter(|s| !s.novel.is_empty()).map(|s| s.input.clone()).collect();
    EvolveReport {
        target,
        cases: executed,
        unique_edges: seen.len(),
        corpus_len: pool.len(),
        promoted,
        crashes,
        discovery,
        promoted_inputs,
        elapsed_ms,
        execs_per_sec: executed as f64 / (elapsed_ms.max(1) as f64 / 1000.0),
        alloc_metered: metered,
        cov_enabled: cov::enabled(),
    }
}

/// Unique edges hit by the plain fixed-seed batch loop at the same
/// budget — the comparison baseline for `evolve beats batch`. Replays
/// [`driver::fuzz_target`]'s exact generation sequence (same RNG
/// derivation), just with per-case coverage capture.
pub fn batch_coverage(target: TargetKind, cases: usize, seed: u64, budgets: &Budgets) -> usize {
    let _quiet = driver::Quiet::new();
    let metered = alloc::probe();
    let mut rng = SplitMix64::new(seed ^ fnv1a(target.as_str().as_bytes()));
    let mut seen: BTreeSet<usize> = BTreeSet::new();
    for _ in 0..cases {
        let input = make_input(target, &mut rng);
        let (_crash, _outcome, edges) = run_case_cov(target, &input, budgets, metered);
        seen.extend(edges);
    }
    seen.len()
}

/// Replay the on-disk corpus with coverage capture: one `(target,
/// edge-set)` entry per target in [`corpus_groups`] order. The
/// coverage-floor regression test asserts these sets against committed
/// floors, and runs the function twice to pin replay determinism.
pub fn replay_corpus_coverage(
    root: &Path,
    budgets: &Budgets,
) -> Result<Vec<(TargetKind, BTreeSet<usize>)>> {
    let _quiet = driver::Quiet::new();
    let metered = alloc::probe();
    let mut out: Vec<(TargetKind, BTreeSet<usize>)> = Vec::new();
    for (sub, targets) in corpus_groups() {
        let dir = root.join(sub);
        let mut paths: Vec<_> = if dir.is_dir() {
            std::fs::read_dir(&dir)
                .with_context(|| format!("reading corpus dir {dir:?}"))?
                .filter_map(|e| e.ok().map(|e| e.path()))
                .filter(|p| p.is_file())
                .collect()
        } else {
            Vec::new()
        };
        paths.sort();
        let mut inputs = Vec::with_capacity(paths.len());
        for path in &paths {
            inputs.push(
                std::fs::read(path).with_context(|| format!("reading corpus file {path:?}"))?,
            );
        }
        for &t in targets {
            let mut seen: BTreeSet<usize> = BTreeSet::new();
            for input in &inputs {
                let (_crash, _outcome, edges) = run_case_cov(t, input, budgets, metered);
                seen.extend(edges);
            }
            out.push((t, seen));
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> EvolveCfg {
        EvolveCfg { seed: 7, cases: 60, max_millis: 0, reminimize_every: 20, ..Default::default() }
    }

    #[test]
    fn evolve_is_byte_reproducible_under_a_fixed_seed() {
        // same seed + same case count ⇒ identical everything, including
        // the promoted corpus bytes (cov on or off)
        let seeds = vec![b"GET / HTTP/1.1\r\nHost: x\r\n".to_vec()];
        let a = evolve_target(TargetKind::Http, &small_cfg(), &seeds);
        let b = evolve_target(TargetKind::Http, &small_cfg(), &seeds);
        assert_eq!(a.cases, b.cases);
        assert_eq!(a.unique_edges, b.unique_edges);
        assert_eq!(a.promoted, b.promoted);
        assert_eq!(a.discovery, b.discovery);
        assert_eq!(a.promoted_inputs, b.promoted_inputs);
        assert!(a.crashes.is_empty(), "http seed corpus must replay clean");
    }

    #[test]
    fn evolve_bootstraps_an_empty_pool_and_stays_clean() {
        for target in [TargetKind::Container, TargetKind::DeltaApply] {
            let r = evolve_target(target, &small_cfg(), &[]);
            assert_eq!(r.cases, 60);
            assert!(r.corpus_len >= 4, "bootstrap seeds missing");
            assert!(
                r.crashes.is_empty(),
                "{:?} evolve found crashes: {:?}",
                target,
                r.crashes.iter().map(|c| c.kind.to_string()).collect::<Vec<_>>()
            );
            assert_eq!(r.cov_enabled, cfg!(feature = "fuzz-cov"));
            if !r.cov_enabled {
                assert_eq!(r.unique_edges, 0);
                assert_eq!(r.promoted, 0);
            }
        }
    }

    #[test]
    fn weighted_pick_is_deterministic_and_in_range() {
        let energy = [0.5, 3.0, 0.25];
        let mut rng = SplitMix64::new(3);
        let picks: Vec<usize> = (0..64).map(|_| pick_weighted(&energy, &mut rng)).collect();
        assert!(picks.iter().all(|&i| i < 3));
        // the heavy seed dominates the schedule
        assert!(picks.iter().filter(|&&i| i == 1).count() > 32);
        let mut rng = SplitMix64::new(3);
        let again: Vec<usize> = (0..64).map(|_| pick_weighted(&energy, &mut rng)).collect();
        assert_eq!(picks, again);
    }

    #[cfg(feature = "fuzz-cov")]
    #[test]
    fn evolve_discovers_edges_and_promotes() {
        let r = evolve_target(TargetKind::Container, &small_cfg(), &[]);
        assert!(r.unique_edges > 0, "instrumented run hit no edges");
        assert!(r.discovery.last().unwrap().1 == r.unique_edges);
        assert_eq!(r.promoted_inputs.len(), r.promoted);
    }
}
