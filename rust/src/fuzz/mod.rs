//! Structure-aware fuzzing + fault injection for every hand-rolled
//! parser and the serve path (dependency-free; `arbitrary`/`cargo-fuzz`
//! are not in the offline registry).
//!
//! The dumb-random battery in `util::ptest::hostile_inputs` almost never
//! survives the `DCBC` magic check, so the deep parsing code — chunk
//! tables, density guards, the streaming state machine, Range
//! arithmetic — was effectively unfuzzed. This subsystem fixes that in
//! three parts:
//!
//! * [`gen`] — grammar-driven generators that emit *syntactically valid*
//!   `.dcbc` containers (real CABAC payloads), HTTP/1.1 request heads,
//!   and `Range` header values from the spec in `docs/FORMAT.md`, plus
//!   a field map (`offset`, `len`, kind) recorded by re-walking the
//!   emitted bytes.
//! * [`mutate`] — format-aware operators over those field maps: varint
//!   length skew, integer-boundary substitution, chunk-table lies,
//!   layer-count lies, truncate-at-field-boundary, header splices,
//!   trailing junk. Mutations are biased *past* the container prelude so
//!   ≥ 50 % of cases reach layer/chunk handling (asserted by
//!   `tests/fuzz_structured.rs`).
//! * [`driver`] — runs each input against a parser target under the
//!   asserted invariants: **never panic** (`catch_unwind`), **never
//!   allocate beyond a budget** (the thread-local meter in [`alloc`],
//!   when installed), **never loop** (per-case wall-clock budget), and
//!   **decode–reencode idempotence** on accepted containers
//!   (`serialize(deserialize(x))` is a fixpoint of
//!   `deserialize∘serialize`). Crashers are ddmin-minimized and written
//!   out for the checked-in corpus (`rust/fuzz_corpus/`), which
//!   [`driver::replay_corpus`] replays deterministically.
//!
//! [`fault`] is the live half: hostile client sessions (byte dribble,
//! slowloris partial heads, mid-request disconnect, stalled readers)
//! thrown at a real server, used by `tests/fault_injection.rs` and the
//! loadgen's `--hostile` mode.
//!
//! On top of the fixed-seed battery sits the coverage-guided layer
//! (PR 10): [`cov`] is a thread-local 64 KiB edge-counter map bumped by
//! `cov::edge!` probes hand-placed at every guard/branch of the hot
//! parsers — compiled to nothing unless the `fuzz-cov` cargo feature is
//! on — and [`evolve`] is the AFL-style corpus-evolution loop (energy
//! scheduling by edge rarity, promotion on new coverage, periodic ddmin
//! re-minimization) that `deepcabac fuzz --evolve` runs per target,
//! deterministic under a fixed seed.
//!
//! Entry points: `deepcabac fuzz` (CLI, used by the CI `fuzz-smoke`
//! job) and the `fuzz_structured` / `fault_injection` test binaries.

pub mod alloc;
pub mod cov;
pub mod driver;
pub mod evolve;
pub mod fault;
pub mod gen;
pub mod mutate;

pub use driver::{
    corpus_groups, ddmin, fuzz_target, replay_corpus, Budgets, Crash, CrashKind, FuzzStats,
    TargetKind,
};
pub use evolve::{batch_coverage, evolve_target, replay_corpus_coverage, EvolveCfg, EvolveReport};
pub use fault::{FaultOutcome, FaultPlan, FaultyConn};
