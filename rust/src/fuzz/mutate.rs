//! Format-aware mutation operators.
//!
//! Dumb bit-flips on a container die at the magic/version check; these
//! operators instead rewrite *fields* located by the map from
//! [`super::gen::map_fields`]: varint length skew, integer-boundary
//! substitution, chunk-table lies, truncation at field boundaries,
//! trailing junk. Mutations are biased past the container prelude
//! (probability [`POST_PRELUDE_BIAS`]) so most mutated inputs still
//! clear [`parse_container_prefix`][crate::model::container::parse_container_prefix]
//! and exercise layer/chunk handling — the coverage proxy
//! `tests/fuzz_structured.rs` asserts on.
//!
//! HTTP heads and `Range` values mutate at the string level with the
//! classic protocol attacks: CRLF injection, header duplication, NUL
//! bytes, oversized values, LF-only line endings, numeric boundaries.

use super::gen::{prelude_end, Field, FieldKind};
use crate::bitstream::write_varint;
use crate::util::SplitMix64;

/// Probability (out of 8) that a mutation is restricted to fields past
/// the container prelude.
pub const POST_PRELUDE_BIAS: u64 = 7;

/// Integer constants sitting on the format's decision boundaries: varint
/// width changes (127/128, 16383/16384), the hostile-header guards
/// (`MAX_CHUNKS`, `MAX_NAME_BYTES`, `MAX_DECODE_ELEMS`) and overflow
/// territory.
pub const BOUNDARY_U64: [u64; 12] = [
    0,
    1,
    127,
    128,
    16383,
    16384,
    (1 << 16) + 1,  // MAX_CHUNKS + 1
    (1 << 20) + 1,  // MAX_NAME_BYTES + 1
    1 << 28,        // MAX_DECODE_ELEMS
    (1 << 28) + 1,  // MAX_DECODE_ELEMS + 1
    u64::MAX / 2 + 1, // Σ of two of these overflows u64 → checked_add paths
    u64::MAX,
];

/// Mutate a serialized container using its field map. Applies 1–3
/// field-level operators (in descending offset order, so earlier splices
/// don't invalidate later offsets) and occasionally appends trailing
/// junk.
pub fn container(bytes: &[u8], fields: &[Field], rng: &mut SplitMix64) -> Vec<u8> {
    let mut out = bytes.to_vec();
    if fields.is_empty() {
        return out;
    }
    let pe = prelude_end(fields);
    let n_ops = 1 + rng.below(3) as usize;
    let mut picks: Vec<usize> = (0..n_ops).map(|_| pick_field(fields, pe, rng)).collect();
    picks.sort_unstable();
    picks.dedup();
    for &fi in picks.iter().rev() {
        apply_field_op(&mut out, fields[fi], pe, rng);
    }
    if rng.next_f64() < 0.15 {
        let n = 1 + rng.below(16);
        out.extend((0..n).map(|_| rng.next_u64() as u8));
    }
    out
}

/// Pick a field index, biased [`POST_PRELUDE_BIAS`]/8 toward fields at
/// or past the prelude end.
fn pick_field(fields: &[Field], pe: usize, rng: &mut SplitMix64) -> usize {
    let post: Vec<usize> = (0..fields.len()).filter(|&i| fields[i].offset >= pe).collect();
    if !post.is_empty() && rng.below(8) < POST_PRELUDE_BIAS {
        post[rng.below(post.len() as u64) as usize]
    } else {
        rng.below(fields.len() as u64) as usize
    }
}

fn apply_field_op(out: &mut Vec<u8>, f: Field, pe: usize, rng: &mut SplitMix64) {
    if f.offset >= out.len() {
        return; // a previous truncation already removed this field
    }
    if f.kind == FieldKind::SkipFlag && rng.below(4) < 3 {
        // targeted: toggle skip/coded (reinterpreting the bytes that
        // follow) or land on the bad-skip-flag reject path
        out[f.offset] = [0u8, 1, 2, 0xFF][rng.below(4) as usize];
        return;
    }
    if f.kind.is_varint() {
        let old = crate::bitstream::read_varint(&out[f.offset..]).map(|(v, _)| v).unwrap_or(0);
        let new = match rng.below(8) {
            0 => old.wrapping_add(1),
            1 => old.wrapping_sub(1),
            2 => old.wrapping_mul(2),
            3 => old / 2,
            4 => old ^ (1 << rng.below(40)),
            _ => BOUNDARY_U64[rng.below(BOUNDARY_U64.len() as u64) as usize],
        };
        splice_varint(out, f, new);
        return;
    }
    // raw field: truncate at the boundary (post-prelude only), blank it,
    // or flip bytes inside it
    match rng.below(4) {
        0 if f.offset >= pe => out.truncate(f.offset + rng.below(f.len as u64 + 1) as usize),
        1 => {
            let end = (f.offset + f.len).min(out.len());
            let fill = if rng.next_u64() & 1 == 0 { 0x00 } else { 0xFF };
            out[f.offset..end].iter_mut().for_each(|b| *b = fill);
        }
        _ => {
            let end = (f.offset + f.len).min(out.len());
            for _ in 0..1 + rng.below(4) {
                if end > f.offset {
                    let p = f.offset + rng.below((end - f.offset) as u64) as usize;
                    out[p] ^= 1 << rng.below(8);
                }
            }
        }
    }
}

/// Replace the varint at `f` with the LEB128 encoding of `new` — the
/// replacement may be a different byte length, so everything after the
/// field shifts.
fn splice_varint(out: &mut Vec<u8>, f: Field, new: u64) {
    let mut enc = Vec::with_capacity(10);
    write_varint(&mut enc, new);
    let end = (f.offset + f.len).min(out.len());
    out.splice(f.offset..end, enc);
}

/// Mutate an HTTP request head at the string/byte level.
pub fn http(head: &[u8], rng: &mut SplitMix64) -> Vec<u8> {
    let mut out = head.to_vec();
    for _ in 0..1 + rng.below(2) {
        if out.is_empty() {
            break;
        }
        match rng.below(8) {
            0 => out.truncate(rng.below(out.len() as u64 + 1) as usize),
            1 => {
                // duplicate one header line
                let lines: Vec<&[u8]> = out.split(|&b| b == b'\n').collect();
                if lines.len() > 1 {
                    let l = lines[rng.below(lines.len() as u64) as usize].to_vec();
                    out.extend_from_slice(&l);
                    out.extend_from_slice(b"\r\n");
                }
            }
            2 => {
                // CRLF injection mid-value
                let p = rng.below(out.len() as u64) as usize;
                out.splice(p..p, *b"\r\nX-Injected: 1");
            }
            3 => {
                let p = rng.below(out.len() as u64) as usize;
                out.insert(p, if rng.next_u64() & 1 == 0 { 0x00 } else { 0xFF });
            }
            4 => {
                // oversized header value (~20 KB, past MAX_HEAD_BYTES)
                out.extend_from_slice(b"X-Big: ");
                out.extend(std::iter::repeat(b'a').take(20 * 1024));
                out.extend_from_slice(b"\r\n");
            }
            5 => {
                // junk method
                let junk: Vec<u8> = (0..1 + rng.below(6)).map(|_| rng.next_u64() as u8).collect();
                out.splice(0..0, junk);
            }
            6 => {
                // LF-only line endings
                out.retain(|&b| b != b'\r');
            }
            _ => {
                let p = rng.below(out.len() as u64) as usize;
                out[p] ^= 1 << rng.below(8);
            }
        }
    }
    out
}

/// Mutate a `Range` header value with numeric-boundary and syntax
/// attacks.
pub fn range(value: &str, rng: &mut SplitMix64) -> String {
    match rng.below(9) {
        0 => {
            // substitute one number with a boundary constant
            let n = BOUNDARY_U64[rng.below(BOUNDARY_U64.len() as u64) as usize];
            match value.split_once('-') {
                Some((a, _)) if rng.next_u64() & 1 == 0 => format!("{a}-{n}"),
                Some((_, b)) => format!("bytes={n}-{b}"),
                None => format!("bytes={n}-"),
            }
        }
        1 => "bytes=-0".into(),
        2 => {
            // beyond u64: no longer parses as an integer
            "bytes=0-99999999999999999999999999".into()
        }
        3 => value.replace('-', "--"),
        4 => format!("{value},{value}"),
        5 => value.replace("bytes", "bytez"),
        6 => format!(" {} ", value.replace('=', " = ")),
        7 => format!("bytes=-{}", u64::MAX),
        _ => {
            let mut b = value.as_bytes().to_vec();
            if !b.is_empty() {
                let p = rng.below(b.len() as u64) as usize;
                b[p] = rng.next_u64() as u8;
            }
            String::from_utf8_lossy(&b).into_owned()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::container::{parse_container_prefix, Parsed};

    #[test]
    fn mutations_mostly_survive_the_prelude() {
        // the structural bias claim behind the coverage proxy: most
        // mutated containers still parse a complete prelude
        let mut rng = SplitMix64::new(77);
        let (mut survived, mut total) = (0usize, 0usize);
        for _ in 0..50 {
            let bytes = super::super::gen::container(&mut rng);
            let fields = super::super::gen::map_fields(&bytes).unwrap();
            for _ in 0..4 {
                let m = container(&bytes, &fields, &mut rng);
                total += 1;
                if matches!(parse_container_prefix(&m), Ok(Parsed::Complete(..))) {
                    survived += 1;
                }
            }
        }
        assert!(
            survived * 2 > total,
            "only {survived}/{total} mutants survived the prelude"
        );
    }

    #[test]
    fn delta_mutations_mostly_survive_the_prelude() {
        // same structural-bias claim for v3 delta segments: the parent
        // fingerprint and skip flags are mapped fields, so mutations
        // stay inside the format instead of dying at the magic check
        let mut rng = SplitMix64::new(79);
        let (mut survived, mut total) = (0usize, 0usize);
        for _ in 0..50 {
            let bytes = super::super::gen::delta_container(&mut rng);
            let fields = super::super::gen::map_fields(&bytes).unwrap();
            for _ in 0..4 {
                let m = container(&bytes, &fields, &mut rng);
                total += 1;
                if matches!(parse_container_prefix(&m), Ok(Parsed::Complete(..))) {
                    survived += 1;
                }
            }
        }
        assert!(
            survived * 2 > total,
            "only {survived}/{total} delta mutants survived the prelude"
        );
    }

    #[test]
    fn mutation_is_deterministic_per_seed() {
        let bytes = {
            let mut rng = SplitMix64::new(3);
            super::super::gen::container(&mut rng)
        };
        let fields = super::super::gen::map_fields(&bytes).unwrap();
        let a = container(&bytes, &fields, &mut SplitMix64::new(42));
        let b = container(&bytes, &fields, &mut SplitMix64::new(42));
        assert_eq!(a, b);
    }

    #[test]
    fn http_and_range_mutators_accept_any_input() {
        let mut rng = SplitMix64::new(13);
        let _ = http(b"", &mut rng);
        let _ = http(b"G", &mut rng);
        let _ = range("", &mut rng);
        let _ = range("bytes=0-1", &mut rng);
        for _ in 0..64 {
            let head = super::super::gen::http_request(&mut rng);
            let _ = http(&head, &mut rng);
            let v = super::super::gen::range_value(&mut rng);
            let _ = range(&v, &mut rng);
        }
    }
}
