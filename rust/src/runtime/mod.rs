//! PJRT runtime — loads the HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the CPU PJRT client.
//! This is how the Rust coordinator measures the accuracy/PSNR of a
//! decompressed model without any Python on the path.
//!
//! Interchange is HLO *text* (not serialized protos): jax ≥ 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).

pub mod eval;
pub mod kernel;

pub use eval::{accuracy_from_logits, psnr, EvalResult};
pub use kernel::RdQuantizeKernel;

use crate::tensor::Tensor;
use anyhow::{Context, Result};
use std::path::Path;

pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    /// CPU PJRT client (the only backend in this environment).
    pub fn cpu() -> Result<Self> {
        Ok(Self { client: xla::PjRtClient::cpu()? })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO text file into an executable.
    pub fn load_hlo_text(&self, path: &Path) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).context("PJRT compile")?;
        Ok(Executable { exe })
    }
}

pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    pub(crate) fn exe_ref(&self) -> &xla::PjRtLoadedExecutable {
        &self.exe
    }

    /// Execute with f32 tensor inputs; returns the elements of the result
    /// tuple as f32 tensors (aot.py lowers with `return_tuple=True`).
    pub fn run_f32(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| {
                let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
                if dims.is_empty() {
                    // rank-0 scalar
                    Ok(xla::Literal::scalar(t.data[0]))
                } else {
                    xla::Literal::vec1(&t.data).reshape(&dims)
                }
            })
            .collect::<Result<_, xla::Error>>()?;
        let mut result = self.exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()?;
        let elems = result.decompose_tuple()?;
        elems
            .into_iter()
            .map(|lit| {
                let shape = lit.array_shape()?;
                let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
                let data = lit.to_vec::<f32>()?;
                Ok(Tensor::new(dims, data))
            })
            .collect()
    }
}
