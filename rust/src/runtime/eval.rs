//! Model-quality evaluation on decompressed weights, via the PJRT
//! executables — the accuracy / PSNR columns of Table 1.

use super::Executable;
use crate::tensor::Tensor;
use anyhow::{bail, Result};

#[derive(Debug, Clone, Copy)]
pub struct EvalResult {
    /// top-1 accuracy for classifiers, PSNR (dB) for autoencoders.
    pub metric: f64,
    pub n_samples: usize,
    pub exec_time_s: f64,
}

/// Top-1 accuracy from a (batch, n_classes) logits tensor.
pub fn accuracy_from_logits(logits: &Tensor, labels: &[i32]) -> f64 {
    let [n, c] = logits.shape[..] else {
        panic!("logits must be rank 2, got {:?}", logits.shape)
    };
    let mut correct = 0usize;
    for i in 0..n {
        let row = &logits.data[i * c..(i + 1) * c];
        let mut arg = 0usize;
        for j in 1..c {
            if row[j] > row[arg] {
                arg = j;
            }
        }
        if arg as i32 == labels[i] {
            correct += 1;
        }
    }
    correct as f64 / n.max(1) as f64
}

/// PSNR (dB) between a reconstruction and its target.
pub fn psnr(recon: &Tensor, target: &Tensor) -> f64 {
    assert_eq!(recon.shape, target.shape);
    let mse: f64 = recon
        .data
        .iter()
        .zip(&target.data)
        .map(|(a, b)| {
            let d = (*a - *b) as f64;
            d * d
        })
        .sum::<f64>()
        / recon.data.len().max(1) as f64;
    -10.0 * (mse + 1e-12).log10()
}

/// Cap on eval batches (env `DEEPCABAC_MAX_EVAL_BATCHES`) so tests can
/// bound the cost of the conv models' interpret-mode forwards.
fn max_batches() -> usize {
    std::env::var("DEEPCABAC_MAX_EVAL_BATCHES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(usize::MAX)
}

/// Evaluate a classifier executable over an eval set, batching at
/// `batch` (the HLO's baked batch size). `params` are the flat weight /
/// bias tensors in manifest `arg_order`.
pub fn eval_classifier(
    exe: &Executable,
    params: &[Tensor],
    eval_x: &Tensor,
    eval_y: &[i32],
    batch: usize,
) -> Result<EvalResult> {
    let n = eval_x.shape[0];
    if n % batch != 0 {
        bail!("eval set size {n} not a multiple of batch {batch}");
    }
    let sample_elems: usize = eval_x.shape[1..].iter().product();
    let timer = crate::util::Timer::new();
    let mut correct_weighted = 0.0f64;
    let n_batches = (n / batch).min(max_batches());
    let n = n_batches * batch;
    for b in 0..n_batches {
        let lo = b * batch * sample_elems;
        let hi = (b + 1) * batch * sample_elems;
        let mut shape = eval_x.shape.clone();
        shape[0] = batch;
        let xb = Tensor::new(shape, eval_x.data[lo..hi].to_vec());
        let mut args: Vec<Tensor> = params.to_vec();
        args.push(xb);
        let out = exe.run_f32(&args)?;
        let logits = &out[0];
        correct_weighted +=
            accuracy_from_logits(logits, &eval_y[b * batch..(b + 1) * batch])
                * batch as f64;
    }
    Ok(EvalResult {
        metric: correct_weighted / n as f64,
        n_samples: n,
        exec_time_s: timer.elapsed_s(),
    })
}

/// Evaluate an autoencoder executable (PSNR against the inputs).
pub fn eval_autoencoder(
    exe: &Executable,
    params: &[Tensor],
    eval_x: &Tensor,
    batch: usize,
) -> Result<EvalResult> {
    let n = eval_x.shape[0];
    if n % batch != 0 {
        bail!("eval set size {n} not a multiple of batch {batch}");
    }
    let sample_elems: usize = eval_x.shape[1..].iter().product();
    let timer = crate::util::Timer::new();
    let mut mse_sum = 0.0f64;
    let n_batches = (n / batch).min(max_batches());
    let n = n_batches * batch;
    for b in 0..n_batches {
        let lo = b * batch * sample_elems;
        let hi = (b + 1) * batch * sample_elems;
        let mut shape = eval_x.shape.clone();
        shape[0] = batch;
        let xb = Tensor::new(shape, eval_x.data[lo..hi].to_vec());
        let mut args: Vec<Tensor> = params.to_vec();
        args.push(xb.clone());
        let out = exe.run_f32(&args)?;
        let recon = &out[0];
        let mse: f64 = recon
            .data
            .iter()
            .zip(&xb.data)
            .map(|(a, b)| {
                let d = (*a - *b) as f64;
                d * d
            })
            .sum::<f64>()
            / recon.data.len() as f64;
        mse_sum += mse;
    }
    let mse = mse_sum / (n / batch) as f64;
    Ok(EvalResult {
        metric: -10.0 * (mse + 1e-12).log10(),
        n_samples: n,
        exec_time_s: timer.elapsed_s(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_counts_argmax() {
        let logits = Tensor::new(vec![3, 2], vec![0.9, 0.1, 0.2, 0.8, 0.6, 0.4]);
        assert!((accuracy_from_logits(&logits, &[0, 1, 1]) - 2.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn psnr_of_identical_is_huge() {
        let t = Tensor::new(vec![4], vec![0.1, 0.2, 0.3, 0.4]);
        assert!(psnr(&t, &t) > 100.0);
        let noisy = Tensor::new(vec![4], vec![0.2, 0.3, 0.4, 0.5]);
        let p = psnr(&noisy, &t);
        assert!((p - 20.0).abs() < 1e-6); // mse = 0.01 ⇒ 20 dB
    }
}
