//! PJRT offload of the L1 `rd_quantize` Pallas kernel.
//!
//! `python/compile/aot.py` exports the blocked weighted-RD argmin kernel
//! (paper eq. 1 with a frozen rate snapshot) as its own HLO artifact at a
//! fixed block shape (N weights, K grid points). This wrapper feeds
//! arbitrary-length tensors through it in N-sized blocks, padding the
//! tail — proving the Rust coordinator can execute the L1 kernel itself,
//! not just whole model forwards.
//!
//! The exact sequential coupling (contexts updated per weight) remains
//! the Rust `RdQuantizer`; the kernel path is the batched approximation
//! used for candidate pre-selection (see kernels/rd_quantize.py). At
//! λ = 0 both are identical (pure weighted nearest-neighbour).

use super::{Executable, Runtime};
use crate::tensor::Tensor;
use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::path::Path;

pub struct RdQuantizeKernel {
    exe: Executable,
    pub block_n: usize,
    pub k: usize,
}

impl RdQuantizeKernel {
    /// Load from the artifacts root (reads `kernels/rd_quantize.json`).
    pub fn load(rt: &Runtime, artifacts: &Path) -> Result<Self> {
        let meta_src = std::fs::read_to_string(artifacts.join("kernels/rd_quantize.json"))
            .context("reading kernels/rd_quantize.json (run `make artifacts`)")?;
        let meta = Json::parse(&meta_src).map_err(|e| anyhow!("kernel meta: {e}"))?;
        let block_n = meta.get("n").and_then(Json::as_usize).context("meta n")?;
        let k = meta.get("k").and_then(Json::as_usize).context("meta k")?;
        let hlo = meta.get("hlo").and_then(Json::as_str).context("meta hlo")?;
        let exe = rt.load_hlo_text(&artifacts.join(hlo))?;
        Ok(Self { exe, block_n, k })
    }

    /// Blocked argmin_k  eta_i (w_i − grid_k)² + λ rate_k.
    ///
    /// `grid`/`rate` must have ≤ K entries; they are padded with a huge
    /// rate so padding never wins. Returns one grid index per weight.
    pub fn run(
        &self,
        weights: &[f32],
        etas: &[f32],
        grid: &[f32],
        rate: &[f32],
        lambda: f32,
    ) -> Result<Vec<i32>> {
        anyhow::ensure!(weights.len() == etas.len(), "w/eta length mismatch");
        anyhow::ensure!(grid.len() == rate.len(), "grid/rate length mismatch");
        anyhow::ensure!(
            grid.len() <= self.k,
            "grid has {} points; kernel block supports {}",
            grid.len(),
            self.k
        );
        // pad tables to K; padded entries get +inf-ish rate so the argmin
        // never selects them
        let mut g = grid.to_vec();
        let mut r = rate.to_vec();
        g.resize(self.k, f32::MAX / 4.0);
        r.resize(self.k, f32::MAX / 4.0);
        let g_t = Tensor::new(vec![self.k], g);
        let r_t = Tensor::new(vec![self.k], r);
        let lam_t = Tensor::new(vec![], vec![lambda]);

        let mut out = Vec::with_capacity(weights.len());
        for chunk_start in (0..weights.len()).step_by(self.block_n) {
            let end = (chunk_start + self.block_n).min(weights.len());
            let mut wb = weights[chunk_start..end].to_vec();
            let mut eb = etas[chunk_start..end].to_vec();
            let valid = wb.len();
            wb.resize(self.block_n, 0.0);
            eb.resize(self.block_n, 1.0);
            let res = self.exe.run_f32_i32(&[
                Tensor::new(vec![self.block_n], wb),
                Tensor::new(vec![self.block_n], eb),
                g_t.clone(),
                r_t.clone(),
                lam_t.clone(),
            ])?;
            out.extend_from_slice(&res[..valid]);
        }
        Ok(out)
    }
}

impl Executable {
    /// Execute with f32 inputs, returning the first tuple element as i32
    /// (the rd_quantize kernel's index output).
    pub fn run_f32_i32(&self, inputs: &[Tensor]) -> Result<Vec<i32>> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| {
                if t.shape.is_empty() {
                    Ok(xla::Literal::scalar(t.data[0]))
                } else {
                    let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
                    xla::Literal::vec1(&t.data).reshape(&dims)
                }
            })
            .collect::<Result<_, xla::Error>>()?;
        let mut result = self.exe_ref().execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()?;
        let elems = result.decompose_tuple()?;
        let first = elems.into_iter().next().context("empty result tuple")?;
        Ok(first.to_vec::<i32>()?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Runtime;
    use crate::util::SplitMix64;

    #[test]
    fn kernel_matches_native_argmin() {
        let artifacts = crate::app::artifacts_dir();
        if !artifacts.join("kernels/rd_quantize.json").exists() {
            eprintln!("skipped: no kernel artifact");
            return;
        }
        let rt = Runtime::cpu().unwrap();
        let kernel = RdQuantizeKernel::load(&rt, &artifacts).unwrap();

        let mut rng = SplitMix64::new(5150);
        let n = 6000; // exercises padding (not a multiple of 4096)
        let w: Vec<f32> = (0..n).map(|_| rng.laplace(0.1) as f32).collect();
        let eta: Vec<f32> = (0..n).map(|_| 0.5 + rng.next_f32()).collect();
        let k = 65;
        let grid: Vec<f32> = (0..k).map(|i| (i as f32 - 32.0) * 0.02).collect();
        let rate: Vec<f32> = (0..k).map(|i| 1.0 + (i as f32 - 32.0).abs() * 0.1).collect();
        let lambda = 0.003f32;

        let got = kernel.run(&w, &eta, &grid, &rate, lambda).unwrap();
        assert_eq!(got.len(), n);
        // native reference argmin
        for i in 0..n {
            let mut best = (0usize, f32::INFINITY);
            for (j, (&q, &r)) in grid.iter().zip(&rate).enumerate() {
                let d = w[i] - q;
                let cost = eta[i] * d * d + lambda * r;
                if cost < best.1 {
                    best = (j, cost);
                }
            }
            assert_eq!(got[i] as usize, best.0, "weight {i}");
        }
    }
}
