//! M-coder probability tables, re-derived from the published design rule
//! (Marpe, Schwarz & Wiegand, "Context-based adaptive binary arithmetic
//! coding in the H.264/AVC video compression standard", 2003, §III):
//!
//! * 63 usable probability states σ = 0..62 with
//!   `p_σ = 0.5 · α^σ`, `α = (p_62 / 0.5)^(1/62)`, `p_62 = 0.01875`.
//! * MPS update: `p ← α·p`            ⇒ `σ ← min(σ+1, 62)`.
//! * LPS update: `p ← α·p + (1 − α)`  ⇒ `σ ← nearest state`, flipping
//!   the MPS when σ = 0.
//! * The coding range R ∈ [2^8, 2^9) is quantized to four cells by bits
//!   7..6; the LPS subrange table stores `round(R_q · p_σ)` (≥ 2) with
//!   `R_q` the cell midpoint.
//!
//! Because the encoder, the decoder, *and* the rate estimator all read
//! the same derived tables, bitstreams are self-consistent; matching the
//! spec's table byte-for-byte is not required (and not claimed).

use once_cell::sync::Lazy;

pub const NUM_STATES: usize = 64;
const ALPHA_P62: f64 = 0.01875;

struct Tables {
    range_lps: [[u16; 4]; NUM_STATES],
    next_mps: [u8; NUM_STATES],
    next_lps: [u8; NUM_STATES],
    bits_mps: [f32; NUM_STATES],
    bits_lps: [f32; NUM_STATES],
    p_lps: [f64; NUM_STATES],
    rate: RateTable,
}

/// Precomputed fractional-bit costs for both bins in every probability
/// state — the H.264/HEVC RDO "fracBits" table, built once. Entry
/// `[state][0]` is the MPS cost, `[state][1]` the LPS cost, so a rate
/// query is one indexed load instead of a log₂ evaluation. This is the
/// table the RD quantizer's estimator (and its memoized tail cache in
/// `codec::estimator`) is built on.
pub struct RateTable {
    pairs: [[f32; 2]; NUM_STATES],
}

impl RateTable {
    /// Cost of coding `bin` in state `(state, mps)`.
    #[inline]
    pub fn bits(&self, state: u8, mps: u8, bin: u8) -> f32 {
        self.pairs[state as usize][(bin != mps) as usize]
    }

    /// Raw (MPS, LPS) cost pair for a state.
    #[inline]
    pub fn pair(&self, state: u8) -> [f32; 2] {
        self.pairs[state as usize]
    }
}

static TABLES: Lazy<Tables> = Lazy::new(|| {
    let alpha = (ALPHA_P62 / 0.5).powf(1.0 / 62.0);
    let mut p = [0.0f64; NUM_STATES];
    for (s, v) in p.iter_mut().enumerate() {
        *v = 0.5 * alpha.powi(s as i32);
    }
    // State 63 is kept as a pseudo-terminal mirror of 62 (we do not code a
    // termination bin; streams are length-delimited by the container).
    p[63] = p[62];

    let mut range_lps = [[0u16; 4]; NUM_STATES];
    for s in 0..NUM_STATES {
        for q in 0..4 {
            // Range cell q covers [256 + 64q, 256 + 64(q+1)); midpoint:
            let rq = 256.0 + 64.0 * q as f64 + 32.0;
            range_lps[s][q] = (rq * p[s]).round().max(2.0) as u16;
        }
    }

    let mut next_mps = [0u8; NUM_STATES];
    let mut next_lps = [0u8; NUM_STATES];
    for s in 0..NUM_STATES {
        next_mps[s] = if s >= 62 { 62 } else { (s + 1) as u8 };
        // LPS: p' = alpha*p + (1-alpha); find nearest state index.
        let p_new = (alpha * p[s] + (1.0 - alpha)).min(0.5);
        let idx = (p_new / 0.5).ln() / alpha.ln();
        next_lps[s] = idx.round().clamp(0.0, 62.0) as u8;
    }

    let mut bits_mps = [0.0f32; NUM_STATES];
    let mut bits_lps = [0.0f32; NUM_STATES];
    for s in 0..NUM_STATES {
        bits_lps[s] = (-p[s].log2()) as f32;
        bits_mps[s] = (-(1.0 - p[s]).log2()) as f32;
    }

    let mut pairs = [[0.0f32; 2]; NUM_STATES];
    for s in 0..NUM_STATES {
        pairs[s] = [bits_mps[s], bits_lps[s]];
    }

    Tables {
        range_lps,
        next_mps,
        next_lps,
        bits_mps,
        bits_lps,
        p_lps: p,
        rate: RateTable { pairs },
    }
});

/// The process-wide [`RateTable`] (built with the coder tables).
#[inline]
pub fn rate_table() -> &'static RateTable {
    &TABLES.rate
}

/// LPS subrange for (state, range-quantizer-cell).
#[inline]
pub fn range_lps(state: u8, q: u32) -> u32 {
    TABLES.range_lps[state as usize][q as usize] as u32
}

#[inline]
pub fn next_state_mps(state: u8) -> u8 {
    TABLES.next_mps[state as usize]
}

#[inline]
pub fn next_state_lps(state: u8) -> u8 {
    TABLES.next_lps[state as usize]
}

/// Fractional bits to code the MPS in `state`.
#[inline]
pub fn entropy_bits_mps(state: u8) -> f32 {
    TABLES.bits_mps[state as usize]
}

/// Fractional bits to code the LPS in `state`.
#[inline]
pub fn entropy_bits_lps(state: u8) -> f32 {
    TABLES.bits_lps[state as usize]
}

/// LPS probability of a state (diagnostics / tests).
pub fn p_lps(state: u8) -> f64 {
    TABLES.p_lps[state as usize]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn state0_is_half() {
        assert!((p_lps(0) - 0.5).abs() < 1e-12);
        assert_eq!(range_lps(0, 3), ((256.0 + 64.0 * 3.0 + 32.0) * 0.5f64).round() as u32);
    }

    #[test]
    fn probabilities_decrease_geometrically() {
        for s in 0..62u8 {
            assert!(p_lps(s + 1) < p_lps(s));
        }
        assert!((p_lps(62) - 0.01875).abs() < 1e-9);
    }

    #[test]
    fn lps_subranges_monotone_in_q_and_state() {
        for s in 0..63u8 {
            for q in 0..3 {
                assert!(range_lps(s, q) <= range_lps(s, q + 1), "s={s} q={q}");
            }
            if s < 61 {
                assert!(range_lps(s + 1, 0) <= range_lps(s, 0));
            }
        }
    }

    #[test]
    fn lps_subrange_lower_bound() {
        for s in 0..NUM_STATES as u8 {
            for q in 0..4 {
                assert!(range_lps(s, q) >= 2);
            }
        }
    }

    #[test]
    fn transitions_in_bounds() {
        for s in 0..NUM_STATES as u8 {
            assert!(next_state_mps(s) <= 62);
            assert!(next_state_lps(s) <= 62);
            // LPS observation cannot make the LPS *less* probable.
            assert!(next_state_lps(s) <= s.max(1));
        }
        assert_eq!(next_state_mps(62), 62);
    }

    #[test]
    fn entropy_bits_consistent_with_p() {
        for s in 0..63u8 {
            let p = p_lps(s);
            assert!((entropy_bits_lps(s) as f64 - (-(p).log2())).abs() < 1e-5);
            assert!((entropy_bits_mps(s) as f64 - (-(1.0 - p).log2())).abs() < 1e-5);
        }
    }

    #[test]
    fn rate_table_matches_entropy_bits() {
        let rt = rate_table();
        for s in 0..NUM_STATES as u8 {
            assert_eq!(rt.bits(s, 0, 0), entropy_bits_mps(s));
            assert_eq!(rt.bits(s, 0, 1), entropy_bits_lps(s));
            assert_eq!(rt.bits(s, 1, 1), entropy_bits_mps(s));
            assert_eq!(rt.bits(s, 1, 0), entropy_bits_lps(s));
            assert_eq!(rt.pair(s), [entropy_bits_mps(s), entropy_bits_lps(s)]);
        }
    }
}
