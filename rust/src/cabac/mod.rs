//! Context-adaptive binary arithmetic coder (CABAC), the paper's §2.
//!
//! This is an H.264/AVC-style M-coder: a multiplication-free binary
//! arithmetic coder over a 64-state probability model per context
//! (Marpe, Schwarz & Wiegand 2003). The probability state machine and
//! the 64x4 LPS range table are *re-derived* from the published design
//! rule (see [`tables`]) rather than copied, which keeps encoder and
//! decoder exactly consistent and lands within a fraction of a percent
//! of the spec tables' efficiency.
//!
//! Key pieces:
//! * [`ContextModel`] — (state, MPS) pair, init at p = 0.5 as the paper
//!   prescribes for network weights.
//! * [`CabacEncoder`] / [`CabacDecoder`] — regular + bypass coding with
//!   **byte-wise** renormalization (whole-byte emit/refill with carry
//!   propagation instead of per-bit loops; bit-identical to the
//!   classic per-bit engine) and the standard flush.
//! * [`tables::RateTable`] — precomputed fractional bit costs per state
//!   used by the rate–distortion quantizer (paper eq. 1's `R_ik`).

pub mod decoder;
pub mod encoder;
pub mod tables;

pub use decoder::CabacDecoder;
pub use encoder::CabacEncoder;

/// One adaptive binary probability model (paper: "context model").
///
/// `state` indexes the 64-entry probability ladder (0 = p_LPS ≈ 0.5,
/// 62 = p_LPS ≈ 0.01875, 63 reserved); `mps` is the current most
/// probable symbol.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ContextModel {
    pub state: u8,
    pub mps: u8,
}

impl Default for ContextModel {
    fn default() -> Self {
        // p(0) = p(1) = 0.5 — the paper's initialization for all bins.
        Self { state: 0, mps: 0 }
    }
}

impl ContextModel {
    pub fn new() -> Self {
        Self::default()
    }

    /// Probability of the LPS under this state (for diagnostics).
    pub fn p_lps(&self) -> f64 {
        tables::p_lps(self.state)
    }

    /// Probability that the *next bin is 1*.
    pub fn p_one(&self) -> f64 {
        if self.mps == 1 {
            1.0 - self.p_lps()
        } else {
            self.p_lps()
        }
    }

    /// Fractional bit cost of coding `bin` in this context *without*
    /// updating the state. This is the estimator behind eq. 1's R_ik —
    /// one load from the precomputed [`tables::RateTable`].
    #[inline]
    pub fn bits(&self, bin: u8) -> f32 {
        tables::rate_table().bits(self.state, self.mps, bin)
    }

    /// State transition exactly as the arithmetic coder applies it.
    #[inline]
    pub fn update(&mut self, bin: u8) {
        if bin == self.mps {
            self.state = tables::next_state_mps(self.state);
        } else {
            if self.state == 0 {
                self.mps ^= 1;
            }
            self.state = tables::next_state_lps(self.state);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_equiprobable() {
        let c = ContextModel::default();
        assert!((c.p_one() - 0.5).abs() < 1e-9);
        assert!((c.bits(0) - 1.0).abs() < 0.01);
        assert!((c.bits(1) - 1.0).abs() < 0.01);
    }

    #[test]
    fn update_moves_towards_observed() {
        let mut c = ContextModel::default();
        for _ in 0..40 {
            c.update(1);
        }
        assert!(c.p_one() > 0.9, "p_one = {}", c.p_one());
        // Costs must mirror: frequent symbol cheap, rare symbol expensive.
        assert!(c.bits(1) < 0.2);
        assert!(c.bits(0) > 3.0);
    }

    #[test]
    fn mps_flips_at_state_zero() {
        let mut c = ContextModel::default();
        assert_eq!(c.mps, 0);
        c.update(1); // LPS at state 0 flips MPS
        assert_eq!(c.mps, 1);
    }

    #[test]
    fn bits_match_update_direction() {
        // After many 1s, coding one more 1 must cost < 1 bit.
        let mut c = ContextModel::default();
        for _ in 0..40 {
            c.update(1);
        }
        assert!(c.bits(1) < 0.1);
    }
}
