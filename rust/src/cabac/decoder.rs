//! CABAC decoder — mirror of the encoder's engine, with **byte-wise
//! refill**: instead of pulling one bit per renormalization step through
//! the bit reader, it keeps up to 56 prefetched stream bits in a 64-bit
//! register and refills whole bytes, so a renorm shift is a single
//! mask/shift. Reads past the end of the payload yield zero bits,
//! matching the writer's zero padding.

use super::{tables, ContextModel};
use crate::bitstream::BitReader;

pub struct CabacDecoder<'a> {
    value: u32,
    range: u32,
    /// Prefetched stream bits: the low `pbits` bits of `pre`, MSB first.
    pre: u64,
    pbits: u32,
    r: BitReader<'a>,
}

impl<'a> CabacDecoder<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        let mut d = Self { value: 0, range: 510, pre: 0, pbits: 0, r: BitReader::new(buf) };
        d.value = d.take(9);
        d
    }

    #[cold]
    fn refill(&mut self) {
        while self.pbits <= 48 {
            self.pre = (self.pre << 8) | self.r.next_byte_or_zero() as u64;
            self.pbits += 8;
        }
    }

    /// Consume the next `n <= 9` stream bits, MSB first.
    #[inline]
    fn take(&mut self, n: u32) -> u32 {
        if self.pbits < n {
            self.refill();
        }
        self.pbits -= n;
        let v = (self.pre >> self.pbits) as u32;
        self.pre &= (1u64 << self.pbits) - 1;
        v
    }

    /// Decode one bin in an adaptive context.
    #[inline]
    pub fn decode(&mut self, ctx: &mut ContextModel) -> u8 {
        let cell = (self.range >> 6) & 3;
        let r_lps = tables::range_lps(ctx.state, cell);
        self.range -= r_lps;
        let bin;
        if self.value < self.range {
            crate::fuzz::cov::edge!("cabac_mps");
            bin = ctx.mps;
            ctx.state = tables::next_state_mps(ctx.state);
        } else {
            crate::fuzz::cov::edge!("cabac_lps");
            self.value -= self.range;
            self.range = r_lps;
            bin = ctx.mps ^ 1;
            if ctx.state == 0 {
                ctx.mps ^= 1;
            }
            ctx.state = tables::next_state_lps(ctx.state);
        }
        if self.range < 256 {
            crate::fuzz::cov::edge!("cabac_renorm");
            let shift = self.range.leading_zeros() - 23;
            self.range <<= shift;
            self.value = (self.value << shift) | self.take(shift);
        }
        bin
    }

    /// Decode one equiprobable (bypass) bin.
    #[inline]
    pub fn decode_bypass(&mut self) -> u8 {
        self.value = (self.value << 1) | self.take(1);
        if self.value >= self.range {
            crate::fuzz::cov::edge!("cabac_bypass_one");
            self.value -= self.range;
            1
        } else {
            0
        }
    }

    /// Decode `n` bypass bins, MSB first.
    #[inline]
    pub fn decode_bypass_bits(&mut self, n: u32) -> u32 {
        let mut v = 0;
        for _ in 0..n {
            v = (v << 1) | self.decode_bypass() as u32;
        }
        v
    }

    /// Exp-Golomb order-k bypass decode.
    ///
    /// 64-bit accumulation mirrors the encoder's overflow fix; hostile
    /// payloads (this decoder also feeds the fuzz tests) saturate at
    /// `u32::MAX` instead of overflowing.
    pub fn decode_bypass_eg(&mut self, k: u32) -> u32 {
        let mut k = k;
        let mut v: u64 = 0;
        while self.decode_bypass() == 1 {
            if k < 63 {
                v = v.saturating_add(1u64 << k);
            }
            k += 1;
            if k > 96 {
                // corrupt/hostile stream: a valid u32 cannot need this
                crate::fuzz::cov::edge!("cabac_eg_break");
                break;
            }
        }
        while k > 0 {
            k -= 1;
            if self.decode_bypass() != 0 && k < 63 {
                v = v.saturating_add(1u64 << k);
            }
        }
        v.min(u32::MAX as u64) as u32
    }

    /// Bits consumed from the underlying reader so far (prefetched but
    /// unconsumed bits excluded).
    pub fn bits_read(&self) -> usize {
        self.r.bit_pos() - self.pbits as usize
    }
}

#[cfg(test)]
mod tests {
    use super::super::CabacEncoder;
    use super::*;

    #[test]
    fn bits_read_excludes_prefetch() {
        let mut enc = CabacEncoder::new();
        let mut ctx = ContextModel::default();
        for i in 0..100u32 {
            enc.encode(&mut ctx, (i & 1) as u8);
        }
        let bytes = enc.finish();
        let mut dec = CabacDecoder::new(&bytes);
        assert_eq!(dec.bits_read(), 9); // the 9-bit init, like the old engine
        let mut ctx = ContextModel::default();
        for i in 0..100u32 {
            assert_eq!(dec.decode(&mut ctx), (i & 1) as u8);
        }
        assert!(dec.bits_read() <= bytes.len() * 8);
    }

    #[test]
    fn hostile_eg_does_not_overflow() {
        // all-ones payload drives the EG prefix as long as possible
        let ones = vec![0xFFu8; 64];
        let mut dec = CabacDecoder::new(&ones);
        let v = dec.decode_bypass_eg(0);
        assert!(v >= 1); // saturates rather than panicking
        // all-zero payload terminates immediately
        let zeros = vec![0u8; 8];
        let mut dec = CabacDecoder::new(&zeros);
        assert_eq!(dec.decode_bypass_eg(0), 0);
    }
}
