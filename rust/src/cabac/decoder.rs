//! CABAC decoder — mirror of the encoder's engine.

use super::{tables, ContextModel};
use crate::bitstream::BitReader;

pub struct CabacDecoder<'a> {
    value: u32,
    range: u32,
    r: BitReader<'a>,
}

impl<'a> CabacDecoder<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        let mut r = BitReader::new(buf);
        let value = r.get_bits(9);
        Self { value, range: 510, r }
    }

    /// Decode one bin in an adaptive context.
    #[inline]
    pub fn decode(&mut self, ctx: &mut ContextModel) -> u8 {
        let q = (self.range >> 6) & 3;
        let r_lps = tables::range_lps(ctx.state, q);
        self.range -= r_lps;
        let bin;
        if self.value < self.range {
            bin = ctx.mps;
            ctx.state = tables::next_state_mps(ctx.state);
        } else {
            self.value -= self.range;
            self.range = r_lps;
            bin = ctx.mps ^ 1;
            if ctx.state == 0 {
                ctx.mps ^= 1;
            }
            ctx.state = tables::next_state_lps(ctx.state);
        }
        while self.range < 256 {
            self.range <<= 1;
            self.value = (self.value << 1) | self.r.get_bit();
        }
        bin
    }

    /// Decode one equiprobable (bypass) bin.
    #[inline]
    pub fn decode_bypass(&mut self) -> u8 {
        self.value = (self.value << 1) | self.r.get_bit();
        if self.value >= self.range {
            self.value -= self.range;
            1
        } else {
            0
        }
    }

    /// Decode `n` bypass bins, MSB first.
    #[inline]
    pub fn decode_bypass_bits(&mut self, n: u32) -> u32 {
        let mut v = 0;
        for _ in 0..n {
            v = (v << 1) | self.decode_bypass() as u32;
        }
        v
    }

    /// Exp-Golomb order-k bypass decode.
    pub fn decode_bypass_eg(&mut self, k: u32) -> u32 {
        let mut k = k;
        let mut v = 0u32;
        while self.decode_bypass() == 1 {
            v += 1 << k;
            k += 1;
        }
        while k > 0 {
            k -= 1;
            v += (self.decode_bypass() as u32) << k;
        }
        v
    }

    /// Bits consumed from the underlying reader so far.
    pub fn bits_read(&self) -> usize {
        self.r.bit_pos()
    }
}
