//! CABAC encoder — AVC-style M-coder with **byte-wise renormalization**.
//!
//! The classic engine (Marpe et al. 2003, fig. 4) renormalizes one bit at
//! a time, with a three-way branch and outstanding-bit bookkeeping per
//! step. This implementation instead accumulates renormalized bits above
//! the 10-bit arithmetic window of a 64-bit `low` register and emits
//! whole bytes, x264-style: carries from later additions ripple through
//! the pending bits by plain integer addition, 0xFF bytes are deferred
//! until the next non-0xFF byte resolves whether a carry reaches them,
//! and a carry past the last extracted byte increments it in place.
//! Bypass bins batch up to 16 at once (`low = (low << n) + range·v`),
//! which turns exp-Golomb suffixes into two shifts.
//!
//! The emitted bitstream is **bit-identical** to the bit-wise engine's
//! (the first renorm bit is the dropped AVC sentinel, and the flush
//! emits `[bit9, bit8, 1]` exactly like the spec flush) — verified by
//! the `bytewise_matches_bitwise_reference` test against a faithful
//! port of the old per-bit implementation.

use super::{tables, ContextModel};
use crate::bitstream::BitWriter;

pub struct CabacEncoder {
    /// Bits 0..9: the arithmetic window. Bits 10..10+q: pending output
    /// (oldest = most significant), still mutable by carries.
    low: u64,
    range: u32,
    /// Pending bit count above the window, *including* the sentinel
    /// until it has been dropped.
    q: u32,
    /// Deferred 0xFF bytes that may still absorb a carry.
    ff: u32,
    /// False until the first byte extraction has dropped the sentinel.
    emitted_any: bool,
    w: BitWriter,
    bins_coded: u64,
}

impl Default for CabacEncoder {
    fn default() -> Self {
        Self::new()
    }
}

impl CabacEncoder {
    pub fn new() -> Self {
        Self {
            low: 0,
            range: 510,
            q: 0,
            ff: 0,
            emitted_any: false,
            w: BitWriter::new(),
            bins_coded: 0,
        }
    }

    pub fn with_capacity(bytes: usize) -> Self {
        Self { w: BitWriter::with_capacity(bytes), ..Self::new() }
    }

    /// Extract completed bytes from the pending region of `low`.
    #[inline]
    fn put_bytes(&mut self) {
        let top = 10 + self.q;
        if (self.low >> top) != 0 {
            // Carry past the pending region: ripples through every
            // deferred 0xFF (making them 0x00) into the last real byte
            // (or the dropped sentinel when nothing has been emitted).
            self.low &= (1u64 << top) - 1;
            self.w.carry_into_last_byte();
            self.w.put_byte_run(0x00, self.ff);
            self.ff = 0;
        }
        loop {
            // The first extraction takes 9 bits and drops the top one
            // (the AVC sentinel — never consumed by the decoder).
            let take = if self.emitted_any { 8 } else { 9 };
            if self.q < take {
                break;
            }
            let shift = 10 + self.q - take;
            let out = ((self.low >> shift) & 0xFF) as u8;
            self.low &= (1u64 << shift) - 1;
            self.q -= take;
            self.emitted_any = true;
            if out == 0xFF {
                self.ff += 1;
            } else {
                self.w.put_byte_run(0xFF, self.ff);
                self.ff = 0;
                self.w.put_byte(out);
            }
        }
    }

    /// Encode one bin in an adaptive context.
    #[inline]
    pub fn encode(&mut self, ctx: &mut ContextModel, bin: u8) {
        self.bins_coded += 1;
        let cell = (self.range >> 6) & 3;
        let r_lps = tables::range_lps(ctx.state, cell);
        self.range -= r_lps;
        if bin != ctx.mps {
            self.low += self.range as u64;
            self.range = r_lps;
            if ctx.state == 0 {
                ctx.mps ^= 1;
            }
            ctx.state = tables::next_state_lps(ctx.state);
        } else {
            ctx.state = tables::next_state_mps(ctx.state);
        }
        if self.range < 256 {
            // range ∈ [2, 255]: whole renorm in one shift instead of a
            // branchy per-bit loop.
            let shift = self.range.leading_zeros() - 23;
            self.range <<= shift;
            self.low <<= shift;
            self.q += shift;
            self.put_bytes();
        }
    }

    /// Batch-encode `n <= 16` equiprobable bins from the low bits of `v`:
    /// n sequential bypass steps collapse to `low·2ⁿ + range·v`.
    #[inline]
    fn bypass_chunk(&mut self, v: u32, n: u32) {
        debug_assert!(n >= 1 && n <= 16 && (v >> n) == 0);
        self.low = (self.low << n) + (self.range as u64) * v as u64;
        self.q += n;
        self.put_bytes();
    }

    /// Encode one equiprobable (bypass) bin.
    #[inline]
    pub fn encode_bypass(&mut self, bin: u8) {
        self.bins_coded += 1;
        self.bypass_chunk((bin & 1) as u32, 1);
    }

    /// Encode `n` bypass bins from the low bits of `v`, MSB first.
    #[inline]
    pub fn encode_bypass_bits(&mut self, v: u32, n: u32) {
        debug_assert!(n <= 32);
        self.bins_coded += n as u64;
        let mut n = n;
        while n > 16 {
            n -= 16;
            self.bypass_chunk((v >> n) & 0xFFFF, 16);
        }
        if n > 0 {
            self.bypass_chunk(v & ((1u32 << n) - 1), n);
        }
    }

    /// Exp-Golomb order-k bypass code for v >= 0.
    ///
    /// All threshold math is 64-bit: for large `v` the running order
    /// reaches 32, where the old `1u32 << k` overflowed (debug panic).
    pub fn encode_bypass_eg(&mut self, v: u32, k: u32) {
        let mut v = v as u64;
        let mut k = k;
        // unary prefix of (1) bins while v >= 2^k
        while k < 63 && v >= (1u64 << k) {
            self.encode_bypass(1);
            v -= 1u64 << k;
            k += 1;
        }
        self.encode_bypass(0);
        // suffix: k bins of v, MSB first (bins above bit 31 are zero)
        while k > 32 {
            let take = (k - 32).min(16);
            self.bins_coded += take as u64;
            self.bypass_chunk(0, take);
            k -= take;
        }
        self.encode_bypass_bits(v as u32, k);
    }

    /// Total bins routed through the engine (regular + bypass).
    pub fn bins_coded(&self) -> u64 {
        self.bins_coded
    }

    /// Bits emitted so far (excluding what is still latent in low/range).
    pub fn bits_written(&self) -> usize {
        self.w.bit_len()
    }

    /// Flush the arithmetic state and return the byte-aligned payload.
    pub fn finish(mut self) -> Vec<u8> {
        // Standard flush. Setting range = 2 makes the renorm exactly 7
        // shifts; then the spec emits [bit9, bit8, 1] of the window.
        self.low <<= 7;
        self.q += 7;
        self.put_bytes();
        self.low = (self.low << 3) | (1 << 10);
        self.q += 3;
        self.put_bytes();
        // Remaining deferred 0xFFs are final (no further additions), then
        // the sub-byte tail, zero-padded by the writer.
        self.w.put_byte_run(0xFF, self.ff);
        self.ff = 0;
        if self.q > 0 {
            let take = if self.emitted_any { self.q } else { self.q - 1 };
            if take > 0 {
                let pend = ((self.low >> 10) & ((1u64 << take) - 1)) as u32;
                self.w.put_bits(pend, take);
            }
        }
        self.w.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::super::CabacDecoder;
    use super::*;

    fn roundtrip(bins: &[u8], n_ctx: usize, pick: impl Fn(usize) -> usize) {
        let mut ctxs = vec![ContextModel::default(); n_ctx];
        let mut enc = CabacEncoder::new();
        for (i, &b) in bins.iter().enumerate() {
            enc.encode(&mut ctxs[pick(i)], b);
        }
        let bytes = enc.finish();
        let mut ctxs = vec![ContextModel::default(); n_ctx];
        let mut dec = CabacDecoder::new(&bytes);
        for (i, &b) in bins.iter().enumerate() {
            assert_eq!(dec.decode(&mut ctxs[pick(i)]), b, "bin {i}");
        }
    }

    #[test]
    fn roundtrip_constant_streams() {
        roundtrip(&[0; 1000], 1, |_| 0);
        roundtrip(&[1; 1000], 1, |_| 0);
    }

    #[test]
    fn roundtrip_alternating() {
        let bins: Vec<u8> = (0..500).map(|i| (i % 2) as u8).collect();
        roundtrip(&bins, 2, |i| i % 2);
    }

    #[test]
    fn skewed_stream_compresses() {
        // 95% zeros through one adaptive context must code well under 1 bpb.
        let mut rng = crate::util::SplitMix64::new(3);
        let bins: Vec<u8> = (0..20_000)
            .map(|_| if rng.next_f64() < 0.95 { 0 } else { 1 })
            .collect();
        let mut ctx = ContextModel::default();
        let mut enc = CabacEncoder::new();
        for &b in &bins {
            enc.encode(&mut ctx, b);
        }
        let bytes = enc.finish();
        let bpb = bytes.len() as f64 * 8.0 / bins.len() as f64;
        // H(0.05) = 0.286; adaptive coder should land below 0.40.
        assert!(bpb < 0.40, "bits/bin = {bpb}");
    }

    #[test]
    fn bypass_roundtrip() {
        let mut enc = CabacEncoder::new();
        let vals = [(0u32, 1u32), (1, 1), (0b1011, 4), (0xffff, 16), (0, 8), (0xdead_beef, 32)];
        for &(v, n) in &vals {
            enc.encode_bypass_bits(v, n);
        }
        let bytes = enc.finish();
        let mut dec = CabacDecoder::new(&bytes);
        for &(v, n) in &vals {
            assert_eq!(dec.decode_bypass_bits(n), v);
        }
    }

    #[test]
    fn exp_golomb_roundtrip() {
        let mut enc = CabacEncoder::new();
        let vals: Vec<u32> = (0..64).chain([100, 1000, 65535, 1 << 20]).collect();
        for &v in &vals {
            enc.encode_bypass_eg(v, 0);
            enc.encode_bypass_eg(v, 2);
        }
        let bytes = enc.finish();
        let mut dec = CabacDecoder::new(&bytes);
        for &v in &vals {
            assert_eq!(dec.decode_bypass_eg(0), v);
            assert_eq!(dec.decode_bypass_eg(2), v);
        }
    }

    #[test]
    fn exp_golomb_u32_max_regression() {
        // The old per-bit EG hit `1u32 << 32` (debug panic) on large
        // remainders; the u64 path must roundtrip the full u32 range.
        let vals = [u32::MAX, u32::MAX - 1, (1 << 31) + 1, 1 << 31];
        let mut enc = CabacEncoder::new();
        for &v in &vals {
            for k in [0, 1, 5] {
                enc.encode_bypass_eg(v, k);
            }
        }
        let bytes = enc.finish();
        let mut dec = CabacDecoder::new(&bytes);
        for &v in &vals {
            for k in [0, 1, 5] {
                assert_eq!(dec.decode_bypass_eg(k), v, "v={v} k={k}");
            }
        }
        // eg_len agrees with the bins actually coded
        let mut enc = CabacEncoder::new();
        enc.encode_bypass_eg(u32::MAX, 0);
        assert_eq!(enc.bins_coded(), crate::codec::estimator::eg_len(u32::MAX, 0) as u64);
    }

    #[test]
    fn mixed_regular_bypass_roundtrip() {
        let mut rng = crate::util::SplitMix64::new(17);
        let mut ctxs = vec![ContextModel::default(); 4];
        let mut enc = CabacEncoder::new();
        let mut script = Vec::new();
        for _ in 0..5000 {
            let regular = rng.next_f64() < 0.7;
            let bin = (rng.next_u64() & 1) as u8;
            let ctx = rng.below(4) as usize;
            if regular {
                enc.encode(&mut ctxs[ctx], bin);
            } else {
                enc.encode_bypass(bin);
            }
            script.push((regular, bin, ctx));
        }
        let bytes = enc.finish();
        let mut ctxs = vec![ContextModel::default(); 4];
        let mut dec = CabacDecoder::new(&bytes);
        for (i, &(regular, bin, ctx)) in script.iter().enumerate() {
            let got = if regular { dec.decode(&mut ctxs[ctx]) } else { dec.decode_bypass() };
            assert_eq!(got, bin, "step {i}");
        }
    }

    // ---- bit-exactness against the old per-bit engine ------------------

    /// Faithful port of the pre-overhaul bit-wise encoder (renorm loop +
    /// outstanding bits), kept as the reference for byte-exactness.
    struct BitwiseRef {
        low: u32,
        range: u32,
        outstanding: u32,
        first_bit: bool,
        w: BitWriter,
    }

    impl BitwiseRef {
        fn new() -> Self {
            Self { low: 0, range: 510, outstanding: 0, first_bit: true, w: BitWriter::new() }
        }

        fn put_bit(&mut self, b: u32) {
            if self.first_bit {
                self.first_bit = false;
            } else {
                self.w.put_bit(b);
            }
            if self.outstanding > 0 {
                self.w.put_run(1 - b, self.outstanding);
                self.outstanding = 0;
            }
        }

        fn renorm(&mut self) {
            while self.range < 256 {
                if self.low >= 512 {
                    self.low -= 512;
                    self.put_bit(1);
                } else if self.low < 256 {
                    self.put_bit(0);
                } else {
                    self.low -= 256;
                    self.outstanding += 1;
                }
                self.low <<= 1;
                self.range <<= 1;
            }
        }

        fn encode(&mut self, ctx: &mut ContextModel, bin: u8) {
            let cell = (self.range >> 6) & 3;
            let r_lps = tables::range_lps(ctx.state, cell);
            self.range -= r_lps;
            if bin != ctx.mps {
                self.low += self.range;
                self.range = r_lps;
                if ctx.state == 0 {
                    ctx.mps ^= 1;
                }
                ctx.state = tables::next_state_lps(ctx.state);
            } else {
                ctx.state = tables::next_state_mps(ctx.state);
            }
            self.renorm();
        }

        fn encode_bypass(&mut self, bin: u8) {
            self.low <<= 1;
            if bin != 0 {
                self.low += self.range;
            }
            if self.low >= 1024 {
                self.low -= 1024;
                self.put_bit(1);
            } else if self.low < 512 {
                self.put_bit(0);
            } else {
                self.low -= 512;
                self.outstanding += 1;
            }
        }

        fn finish(mut self) -> Vec<u8> {
            self.range = 2;
            self.renorm();
            self.put_bit((self.low >> 9) & 1);
            let tail = ((self.low >> 7) & 3) | 1;
            self.w.put_bits(tail, 2);
            self.w.finish()
        }
    }

    #[test]
    fn bytewise_matches_bitwise_reference() {
        // Randomized scripts of regular + bypass bins across styles that
        // stress carries (bypass-1 runs -> 0xFF bytes) and MPS runs.
        let mut rng = crate::util::SplitMix64::new(0xBEEF);
        for case in 0..40 {
            let n = (rng.below(4000) + 1) as usize;
            let p_bypass = match case % 3 {
                0 => 0.2,
                1 => 0.7,
                _ => 0.95, // heavy bypass: maximal carry pressure
            };
            let script: Vec<(bool, u8, usize)> = (0..n)
                .map(|_| {
                    let byp = rng.next_f64() < p_bypass;
                    let bin = if case % 2 == 0 {
                        (rng.next_u64() & 1) as u8
                    } else {
                        // skew towards 1 to generate long 0xFF runs
                        (rng.next_f64() < 0.9) as u8
                    };
                    (byp, bin, rng.below(3) as usize)
                })
                .collect();
            let mut a = CabacEncoder::new();
            let mut b = BitwiseRef::new();
            let mut ctx_a = vec![ContextModel::default(); 3];
            let mut ctx_b = vec![ContextModel::default(); 3];
            for &(byp, bin, c) in &script {
                if byp {
                    a.encode_bypass(bin);
                    b.encode_bypass(bin);
                } else {
                    a.encode(&mut ctx_a[c], bin);
                    b.encode(&mut ctx_b[c], bin);
                }
            }
            assert_eq!(a.finish(), b.finish(), "case {case} (n={n})");
        }
    }
}
