//! CABAC encoder — standard AVC-style arithmetic encoding engine with
//! outstanding-bit bookkeeping (Marpe et al. 2003, fig. 4).

use super::{tables, ContextModel};
use crate::bitstream::BitWriter;

pub struct CabacEncoder {
    low: u32,
    range: u32,
    outstanding: u32,
    first_bit: bool,
    w: BitWriter,
    bins_coded: u64,
}

impl Default for CabacEncoder {
    fn default() -> Self {
        Self::new()
    }
}

impl CabacEncoder {
    pub fn new() -> Self {
        Self {
            low: 0,
            range: 510,
            outstanding: 0,
            first_bit: true,
            w: BitWriter::new(),
            bins_coded: 0,
        }
    }

    pub fn with_capacity(bytes: usize) -> Self {
        Self { w: BitWriter::with_capacity(bytes), ..Self::new() }
    }

    #[inline]
    fn put_bit(&mut self, b: u32) {
        // The very first renorm output bit of the stream is a sentinel the
        // decoder never consumes; we drop it like the AVC spec does.
        if self.first_bit {
            self.first_bit = false;
        } else {
            self.w.put_bit(b);
        }
        if self.outstanding > 0 {
            self.w.put_run(1 - b, self.outstanding);
            self.outstanding = 0;
        }
    }

    #[inline]
    fn renorm(&mut self) {
        while self.range < 256 {
            if self.low >= 512 {
                self.low -= 512;
                self.put_bit(1);
            } else if self.low < 256 {
                self.put_bit(0);
            } else {
                self.low -= 256;
                self.outstanding += 1;
            }
            self.low <<= 1;
            self.range <<= 1;
        }
    }

    /// Encode one bin in an adaptive context.
    #[inline]
    pub fn encode(&mut self, ctx: &mut ContextModel, bin: u8) {
        self.bins_coded += 1;
        let q = (self.range >> 6) & 3;
        let r_lps = tables::range_lps(ctx.state, q);
        self.range -= r_lps;
        if bin != ctx.mps {
            self.low += self.range;
            self.range = r_lps;
            if ctx.state == 0 {
                ctx.mps ^= 1;
            }
            ctx.state = tables::next_state_lps(ctx.state);
        } else {
            ctx.state = tables::next_state_mps(ctx.state);
        }
        self.renorm();
    }

    /// Encode one equiprobable (bypass) bin.
    #[inline]
    pub fn encode_bypass(&mut self, bin: u8) {
        self.bins_coded += 1;
        self.low <<= 1;
        if bin != 0 {
            self.low += self.range;
        }
        if self.low >= 1024 {
            self.low -= 1024;
            self.put_bit(1);
        } else if self.low < 512 {
            self.put_bit(0);
        } else {
            self.low -= 512;
            self.outstanding += 1;
        }
    }

    /// Encode `n` bypass bins from the low bits of `v`, MSB first.
    #[inline]
    pub fn encode_bypass_bits(&mut self, v: u32, n: u32) {
        for i in (0..n).rev() {
            self.encode_bypass(((v >> i) & 1) as u8);
        }
    }

    /// Exp-Golomb order-k bypass code for v >= 0.
    pub fn encode_bypass_eg(&mut self, v: u32, k: u32) {
        let mut v = v;
        let mut k = k;
        // unary prefix of (1) bits while v >= 2^k
        loop {
            if v >= (1 << k) {
                self.encode_bypass(1);
                v -= 1 << k;
                k += 1;
            } else {
                self.encode_bypass(0);
                while k > 0 {
                    k -= 1;
                    self.encode_bypass(((v >> k) & 1) as u8);
                }
                break;
            }
        }
    }

    /// Total bins routed through the engine (regular + bypass).
    pub fn bins_coded(&self) -> u64 {
        self.bins_coded
    }

    /// Bits emitted so far (excluding what is still latent in low/range).
    pub fn bits_written(&self) -> usize {
        self.w.bit_len()
    }

    /// Flush the arithmetic state and return the byte-aligned payload.
    pub fn finish(mut self) -> Vec<u8> {
        // Standard flush: 2 final decisions worth of low bits.
        self.range = 2;
        self.renorm();
        self.put_bit((self.low >> 9) & 1);
        let tail = ((self.low >> 7) & 3) | 1;
        self.w.put_bits(tail, 2);
        self.w.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::super::CabacDecoder;
    use super::*;

    fn roundtrip(bins: &[u8], n_ctx: usize, pick: impl Fn(usize) -> usize) {
        let mut ctxs = vec![ContextModel::default(); n_ctx];
        let mut enc = CabacEncoder::new();
        for (i, &b) in bins.iter().enumerate() {
            enc.encode(&mut ctxs[pick(i)], b);
        }
        let bytes = enc.finish();
        let mut ctxs = vec![ContextModel::default(); n_ctx];
        let mut dec = CabacDecoder::new(&bytes);
        for (i, &b) in bins.iter().enumerate() {
            assert_eq!(dec.decode(&mut ctxs[pick(i)]), b, "bin {i}");
        }
    }

    #[test]
    fn roundtrip_constant_streams() {
        roundtrip(&[0; 1000], 1, |_| 0);
        roundtrip(&[1; 1000], 1, |_| 0);
    }

    #[test]
    fn roundtrip_alternating() {
        let bins: Vec<u8> = (0..500).map(|i| (i % 2) as u8).collect();
        roundtrip(&bins, 2, |i| i % 2);
    }

    #[test]
    fn skewed_stream_compresses() {
        // 95% zeros through one adaptive context must code well under 1 bpb.
        let mut rng = crate::util::SplitMix64::new(3);
        let bins: Vec<u8> = (0..20_000)
            .map(|_| if rng.next_f64() < 0.95 { 0 } else { 1 })
            .collect();
        let mut ctx = ContextModel::default();
        let mut enc = CabacEncoder::new();
        for &b in &bins {
            enc.encode(&mut ctx, b);
        }
        let bytes = enc.finish();
        let bpb = bytes.len() as f64 * 8.0 / bins.len() as f64;
        // H(0.05) = 0.286; adaptive coder should land below 0.40.
        assert!(bpb < 0.40, "bits/bin = {bpb}");
    }

    #[test]
    fn bypass_roundtrip() {
        let mut enc = CabacEncoder::new();
        let vals = [(0u32, 1u32), (1, 1), (0b1011, 4), (0xffff, 16), (0, 8)];
        for &(v, n) in &vals {
            enc.encode_bypass_bits(v, n);
        }
        let bytes = enc.finish();
        let mut dec = CabacDecoder::new(&bytes);
        for &(v, n) in &vals {
            assert_eq!(dec.decode_bypass_bits(n), v);
        }
    }

    #[test]
    fn exp_golomb_roundtrip() {
        let mut enc = CabacEncoder::new();
        let vals: Vec<u32> = (0..64).chain([100, 1000, 65535, 1 << 20]).collect();
        for &v in &vals {
            enc.encode_bypass_eg(v, 0);
            enc.encode_bypass_eg(v, 2);
        }
        let bytes = enc.finish();
        let mut dec = CabacDecoder::new(&bytes);
        for &v in &vals {
            assert_eq!(dec.decode_bypass_eg(0), v);
            assert_eq!(dec.decode_bypass_eg(2), v);
        }
    }

    #[test]
    fn mixed_regular_bypass_roundtrip() {
        let mut rng = crate::util::SplitMix64::new(17);
        let mut ctxs = vec![ContextModel::default(); 4];
        let mut enc = CabacEncoder::new();
        let mut script = Vec::new();
        for _ in 0..5000 {
            let regular = rng.next_f64() < 0.7;
            let bin = (rng.next_u64() & 1) as u8;
            let ctx = rng.below(4) as usize;
            if regular {
                enc.encode(&mut ctxs[ctx], bin);
            } else {
                enc.encode_bypass(bin);
            }
            script.push((regular, bin, ctx));
        }
        let bytes = enc.finish();
        let mut ctxs = vec![ContextModel::default(); 4];
        let mut dec = CabacDecoder::new(&bytes);
        for (i, &(regular, bin, ctx)) in script.iter().enumerate() {
            let got = if regular { dec.decode(&mut ctxs[ctx]) } else { dec.decode_bypass() };
            assert_eq!(got, bin, "step {i}");
        }
    }
}
