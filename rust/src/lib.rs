//! DeepCABAC — context-adaptive binary arithmetic coding for deep neural
//! network compression.
//!
//! Reproduction of Wiedemann et al., "DeepCABAC: Context-adaptive binary
//! arithmetic coding for deep neural network compression" (ICML 2019
//! workshop / arXiv:1905.08318).
//!
//! Architecture (three layers, Python never on the hot path):
//!   * L3 (this crate): the CABAC entropy codec, the weighted
//!     rate-distortion quantizer, the per-layer compression pipeline,
//!     baselines, and the PJRT runtime used to evaluate compressed models.
//!   * L2 (python/compile): JAX model definitions whose forward passes are
//!     AOT-lowered to HLO text artifacts consumed by [`runtime`].
//!   * L1 (python/compile/kernels): Pallas kernels (matmul, im2col conv,
//!     blocked RD argmin) called from L2, validated against pure-jnp
//!     oracles at build time.

pub mod app;
pub mod baselines;
pub mod bayes;
pub mod bitstream;
pub mod cabac;
pub mod cli;
pub mod codec;
pub mod coordinator;
pub mod delta;
pub mod fuzz;
pub mod model;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod serve;
pub mod synth;
pub mod tensor;
pub mod util;

pub use bitstream::{BitReader, BitWriter};
pub use cabac::{CabacDecoder, CabacEncoder, ContextModel};
