//! Table / CSV / human-readable output for the benches and examples.

/// Format bytes with the units Table 1 uses.
pub fn human_bytes(b: usize) -> String {
    if b >= 10_000_000 {
        format!("{:.2} MB", b as f64 / 1e6)
    } else if b >= 10_000 {
        format!("{:.1} KB", b as f64 / 1e3)
    } else {
        format!("{b} B")
    }
}

/// A fixed-column markdown-ish table writer.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Self {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let line = |cells: &[String], widths: &[usize], out: &mut String| {
            out.push('|');
            for (c, w) in cells.iter().zip(widths) {
                out.push_str(&format!(" {:<w$} |", c, w = w));
            }
            out.push('\n');
        };
        line(&self.headers, &widths, &mut out);
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{:-<w$}|", "", w = w + 2));
        }
        out.push('\n');
        for row in &self.rows {
            line(row, &widths, &mut out);
        }
        out
    }
}

/// CSV writer (no quoting needs beyond commas in our data).
pub fn to_csv(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut out = headers.join(",");
    out.push('\n');
    for r in rows {
        out.push_str(&r.join(","));
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_formatting() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(17_000), "17.0 KB");
        assert_eq!(human_bytes(553_430_000), "553.43 MB");
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["model", "ratio"]);
        t.row(vec!["vgg16".into(), "1.57".into()]);
        let s = t.render();
        assert!(s.contains("| model | ratio |"));
        assert!(s.contains("| vgg16 | 1.57  |"));
    }

    #[test]
    #[should_panic(expected = "row arity mismatch")]
    fn table_rejects_bad_rows() {
        let mut t = Table::new(&["a"]);
        t.row(vec!["1".into(), "2".into()]);
    }
}
