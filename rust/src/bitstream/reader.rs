//! MSB-first bit reader.

/// Reads bits MSB-first from a byte slice. Reads past the end return 0,
/// matching the zero padding produced by `BitWriter::finish` — entropy
/// decoders terminate on symbol counts, not on stream exhaustion.
#[derive(Debug, Clone)]
pub struct BitReader<'a> {
    buf: &'a [u8],
    pos: usize, // bit position
}

impl<'a> BitReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    /// Read one bit; past-the-end reads yield 0.
    #[inline]
    pub fn get_bit(&mut self) -> u32 {
        let byte = self.pos >> 3;
        let bit = if byte < self.buf.len() {
            ((self.buf[byte] >> (7 - (self.pos & 7))) & 1) as u32
        } else {
            0
        };
        self.pos += 1;
        bit
    }

    /// Read `n <= 32` bits MSB-first.
    #[inline]
    pub fn get_bits(&mut self, n: u32) -> u32 {
        debug_assert!(n <= 32);
        let mut v = 0u32;
        for _ in 0..n {
            v = (v << 1) | self.get_bit();
        }
        v
    }

    /// Read a whole byte from a byte-aligned position; past-the-end
    /// reads yield 0. The byte-refill CABAC decoder's fast path.
    #[inline]
    pub fn next_byte_or_zero(&mut self) -> u8 {
        debug_assert_eq!(self.pos & 7, 0, "byte reads require alignment");
        let byte = self.pos >> 3;
        let b = if byte < self.buf.len() { self.buf[byte] } else { 0 };
        self.pos += 8;
        b
    }

    /// Current bit position.
    pub fn bit_pos(&self) -> usize {
        self.pos
    }

    /// True once the position has passed the last real byte.
    pub fn exhausted(&self) -> bool {
        self.pos >= self.buf.len() * 8
    }
}

#[cfg(test)]
mod tests {
    use super::super::BitWriter;
    use super::*;

    #[test]
    fn roundtrip_bits() {
        let mut w = BitWriter::new();
        let vals = [(0b1u32, 1u32), (0b0, 1), (0xdead, 16), (0x3, 2), (0x1f, 5)];
        for &(v, n) in &vals {
            w.put_bits(v, n);
        }
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        for &(v, n) in &vals {
            assert_eq!(r.get_bits(n), v);
        }
    }

    #[test]
    fn past_end_reads_zero() {
        let mut r = BitReader::new(&[0xff]);
        assert_eq!(r.get_bits(8), 0xff);
        assert_eq!(r.get_bits(8), 0);
        assert!(r.exhausted());
    }

    #[test]
    fn byte_reads_match_bit_reads() {
        let data = [0xDE, 0xAD, 0xBE];
        let mut a = BitReader::new(&data);
        let mut b = BitReader::new(&data);
        for _ in 0..5 {
            assert_eq!(a.next_byte_or_zero() as u32, b.get_bits(8));
        }
        assert_eq!(a.bit_pos(), b.bit_pos());
    }
}
