//! Bit-level I/O used by every entropy coder in the crate.
//!
//! [`BitWriter`] accumulates bits MSB-first into a byte buffer;
//! [`BitReader`] reads them back. Both are deliberately simple and fully
//! deterministic so that bitstreams are reproducible across platforms.

mod reader;
mod writer;

pub use reader::BitReader;
pub use writer::BitWriter;

/// Write a u64 as a LEB128-style varint (7 bits per byte, MSB = continue).
pub fn write_varint(buf: &mut Vec<u8>, mut v: u64) {
    loop {
        let byte = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            buf.push(byte);
            return;
        }
        buf.push(byte | 0x80);
    }
}

/// Read a varint written by [`write_varint`]. Returns (value, bytes read).
pub fn read_varint(buf: &[u8]) -> Option<(u64, usize)> {
    let mut v: u64 = 0;
    let mut shift = 0u32;
    for (i, &byte) in buf.iter().enumerate() {
        if shift >= 64 {
            return None;
        }
        v |= ((byte & 0x7f) as u64) << shift;
        if byte & 0x80 == 0 {
            return Some((v, i + 1));
        }
        shift += 7;
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn varint_roundtrip() {
        for v in [0u64, 1, 127, 128, 300, 16383, 16384, u32::MAX as u64, u64::MAX] {
            let mut buf = Vec::new();
            write_varint(&mut buf, v);
            let (got, n) = read_varint(&buf).unwrap();
            assert_eq!(got, v);
            assert_eq!(n, buf.len());
        }
    }

    #[test]
    fn varint_truncated_is_none() {
        let mut buf = Vec::new();
        write_varint(&mut buf, u64::MAX);
        assert!(read_varint(&buf[..buf.len() - 1]).is_none());
    }
}
