//! MSB-first bit writer.

/// Accumulates bits MSB-first into a 64-bit staging word and flushes
/// whole words into the byte buffer — one branch per bit instead of a
/// byte push every 8 bits (§Perf: the CABAC renorm loop calls
/// [`put_bit`] for every renormalization step).
#[derive(Debug, Default, Clone)]
pub struct BitWriter {
    buf: Vec<u8>,
    /// Bits pending in `acc` (0..=63), packed from the LSB upward.
    acc: u64,
    nbits: u32,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_capacity(bytes: usize) -> Self {
        Self { buf: Vec::with_capacity(bytes), acc: 0, nbits: 0 }
    }

    /// Append a single bit (LSB of `bit`).
    #[inline]
    pub fn put_bit(&mut self, bit: u32) {
        self.acc = (self.acc << 1) | (bit & 1) as u64;
        self.nbits += 1;
        if self.nbits == 64 {
            self.buf.extend_from_slice(&self.acc.to_be_bytes());
            self.acc = 0;
            self.nbits = 0;
        }
    }

    /// Append the `n` low bits of `v`, MSB-first. `n <= 32`.
    #[inline]
    pub fn put_bits(&mut self, v: u32, n: u32) {
        debug_assert!(n <= 32);
        if n == 0 {
            return;
        }
        if self.nbits + n <= 64 {
            self.acc = (self.acc << n) | (v & mask(n)) as u64;
            self.nbits += n;
            if self.nbits == 64 {
                self.buf.extend_from_slice(&self.acc.to_be_bytes());
                self.acc = 0;
                self.nbits = 0;
            }
        } else {
            for i in (0..n).rev() {
                self.put_bit((v >> i) & 1);
            }
        }
    }

    /// Append `n` copies of `bit` (the CABAC outstanding-bits pattern).
    #[inline]
    pub fn put_run(&mut self, bit: u32, mut n: u32) {
        let fill = if bit & 1 == 1 { u32::MAX } else { 0 };
        while n >= 32 {
            self.put_bits(fill, 32);
            n -= 32;
        }
        if n > 0 {
            self.put_bits(fill & mask(n), n);
        }
    }

    /// Number of bits written so far.
    pub fn bit_len(&self) -> usize {
        self.buf.len() * 8 + self.nbits as usize
    }

    /// Pad with zero bits to the next byte boundary and return the buffer.
    pub fn finish(mut self) -> Vec<u8> {
        // flush full bytes out of the staging word
        while self.nbits >= 8 {
            let shift = self.nbits - 8;
            self.buf.push(((self.acc >> shift) & 0xff) as u8);
            self.nbits -= 8;
        }
        if self.nbits > 0 {
            let byte = ((self.acc << (8 - self.nbits)) & 0xff) as u8;
            self.buf.push(byte);
        }
        self.buf
    }

    /// Byte-align (zero padding) without consuming the writer.
    pub fn align(&mut self) {
        while self.nbits % 8 != 0 {
            self.put_bit(0);
        }
    }

    /// Borrow the already-complete bytes (staged bits not included).
    pub fn bytes(&self) -> &[u8] {
        &self.buf
    }
}

#[inline]
fn mask(n: u32) -> u32 {
    if n >= 32 {
        u32::MAX
    } else {
        (1u32 << n) - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bits_msb_first() {
        let mut w = BitWriter::new();
        w.put_bit(1);
        w.put_bit(0);
        w.put_bit(1);
        let out = w.finish();
        assert_eq!(out, vec![0b1010_0000]);
    }

    #[test]
    fn multi_bit_write() {
        let mut w = BitWriter::new();
        w.put_bits(0b1101, 4);
        w.put_bits(0xAB, 8);
        let out = w.finish();
        assert_eq!(out, vec![0b1101_1010, 0b1011_0000]);
    }

    #[test]
    fn bit_len_tracks() {
        let mut w = BitWriter::new();
        assert_eq!(w.bit_len(), 0);
        w.put_bits(0, 13);
        assert_eq!(w.bit_len(), 13);
    }

    #[test]
    fn long_streams_cross_word_boundaries() {
        let mut w = BitWriter::new();
        for i in 0..1000u32 {
            w.put_bits(i & 0x1ff, 9);
        }
        let out = w.finish();
        assert_eq!(out.len(), (1000 * 9 + 7) / 8);
        // spot-check via reader
        let mut r = crate::bitstream::BitReader::new(&out);
        for i in 0..1000u32 {
            assert_eq!(r.get_bits(9), i & 0x1ff, "i={i}");
        }
    }

    #[test]
    fn put_run_matches_individual_bits() {
        let mut a = BitWriter::new();
        let mut b = BitWriter::new();
        a.put_bits(0b101, 3);
        b.put_bits(0b101, 3);
        a.put_run(1, 75);
        for _ in 0..75 {
            b.put_bit(1);
        }
        a.put_run(0, 5);
        for _ in 0..5 {
            b.put_bit(0);
        }
        assert_eq!(a.finish(), b.finish());
    }
}
