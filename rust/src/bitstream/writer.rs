//! MSB-first bit writer.

/// Accumulates bits MSB-first into a 64-bit staging word and flushes
/// whole words into the byte buffer — one branch per bit instead of a
/// byte push every 8 bits (§Perf: the CABAC renorm loop calls
/// [`put_bit`] for every renormalization step).
#[derive(Debug, Default, Clone)]
pub struct BitWriter {
    buf: Vec<u8>,
    /// Bits pending in `acc` (0..=63), packed from the LSB upward.
    acc: u64,
    nbits: u32,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_capacity(bytes: usize) -> Self {
        Self { buf: Vec::with_capacity(bytes), acc: 0, nbits: 0 }
    }

    /// Append a single bit (LSB of `bit`).
    #[inline]
    pub fn put_bit(&mut self, bit: u32) {
        self.acc = (self.acc << 1) | (bit & 1) as u64;
        self.nbits += 1;
        if self.nbits == 64 {
            self.buf.extend_from_slice(&self.acc.to_be_bytes());
            self.acc = 0;
            self.nbits = 0;
        }
    }

    /// Append the `n` low bits of `v`, MSB-first. `n <= 32`.
    #[inline]
    pub fn put_bits(&mut self, v: u32, n: u32) {
        debug_assert!(n <= 32);
        if n == 0 {
            return;
        }
        if self.nbits + n <= 64 {
            self.acc = (self.acc << n) | (v & mask(n)) as u64;
            self.nbits += n;
            if self.nbits == 64 {
                self.buf.extend_from_slice(&self.acc.to_be_bytes());
                self.acc = 0;
                self.nbits = 0;
            }
        } else {
            for i in (0..n).rev() {
                self.put_bit((v >> i) & 1);
            }
        }
    }

    /// Append `n` copies of `bit` (the CABAC outstanding-bits pattern).
    #[inline]
    pub fn put_run(&mut self, bit: u32, mut n: u32) {
        let fill = if bit & 1 == 1 { u32::MAX } else { 0 };
        while n >= 32 {
            self.put_bits(fill, 32);
            n -= 32;
        }
        if n > 0 {
            self.put_bits(fill & mask(n), n);
        }
    }

    /// Number of bits written so far.
    pub fn bit_len(&self) -> usize {
        self.buf.len() * 8 + self.nbits as usize
    }

    /// Pad with zero bits to the next byte boundary and return the buffer.
    pub fn finish(mut self) -> Vec<u8> {
        // flush full bytes out of the staging word
        while self.nbits >= 8 {
            let shift = self.nbits - 8;
            self.buf.push(((self.acc >> shift) & 0xff) as u8);
            self.nbits -= 8;
        }
        if self.nbits > 0 {
            let byte = ((self.acc << (8 - self.nbits)) & 0xff) as u8;
            self.buf.push(byte);
        }
        self.buf
    }

    /// Byte-align (zero padding) without consuming the writer.
    pub fn align(&mut self) {
        while self.nbits % 8 != 0 {
            self.put_bit(0);
        }
    }

    /// Append a whole byte. On a byte-aligned writer this is a plain
    /// `Vec::push` — the fast path the byte-wise CABAC renormalization
    /// relies on; unaligned writers fall back to the bit path.
    #[inline]
    pub fn put_byte(&mut self, byte: u8) {
        if self.nbits == 0 {
            self.buf.push(byte);
        } else {
            self.put_bits(byte as u32, 8);
        }
    }

    /// Append `n` copies of `byte` (CABAC outstanding-0xFF resolution).
    #[inline]
    pub fn put_byte_run(&mut self, byte: u8, n: u32) {
        if self.nbits == 0 {
            let len = self.buf.len();
            self.buf.resize(len + n as usize, byte);
        } else {
            for _ in 0..n {
                self.put_bits(byte as u32, 8);
            }
        }
    }

    /// Propagate an arithmetic-coder carry into the last completed byte.
    /// No-op on an empty buffer (the CABAC encoder's dropped sentinel bit
    /// absorbs a leading carry). The caller must guarantee the last byte
    /// is not 0xFF (the coder defers 0xFF bytes until carries resolve).
    #[inline]
    pub fn carry_into_last_byte(&mut self) {
        debug_assert_eq!(self.nbits, 0, "carry requires a byte-aligned writer");
        if let Some(last) = self.buf.last_mut() {
            debug_assert_ne!(*last, 0xFF, "carry would overflow a deferred byte");
            *last = last.wrapping_add(1);
        }
    }

    /// Borrow the already-complete bytes (staged bits not included).
    pub fn bytes(&self) -> &[u8] {
        &self.buf
    }
}

#[inline]
fn mask(n: u32) -> u32 {
    if n >= 32 {
        u32::MAX
    } else {
        (1u32 << n) - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bits_msb_first() {
        let mut w = BitWriter::new();
        w.put_bit(1);
        w.put_bit(0);
        w.put_bit(1);
        let out = w.finish();
        assert_eq!(out, vec![0b1010_0000]);
    }

    #[test]
    fn multi_bit_write() {
        let mut w = BitWriter::new();
        w.put_bits(0b1101, 4);
        w.put_bits(0xAB, 8);
        let out = w.finish();
        assert_eq!(out, vec![0b1101_1010, 0b1011_0000]);
    }

    #[test]
    fn bit_len_tracks() {
        let mut w = BitWriter::new();
        assert_eq!(w.bit_len(), 0);
        w.put_bits(0, 13);
        assert_eq!(w.bit_len(), 13);
    }

    #[test]
    fn long_streams_cross_word_boundaries() {
        let mut w = BitWriter::new();
        for i in 0..1000u32 {
            w.put_bits(i & 0x1ff, 9);
        }
        let out = w.finish();
        assert_eq!(out.len(), (1000 * 9 + 7) / 8);
        // spot-check via reader
        let mut r = crate::bitstream::BitReader::new(&out);
        for i in 0..1000u32 {
            assert_eq!(r.get_bits(9), i & 0x1ff, "i={i}");
        }
    }

    #[test]
    fn byte_api_matches_bit_api() {
        let mut a = BitWriter::new();
        let mut b = BitWriter::new();
        a.put_byte(0xA5);
        a.put_byte_run(0x3C, 3);
        b.put_bits(0xA5, 8);
        for _ in 0..3 {
            b.put_bits(0x3C, 8);
        }
        assert_eq!(a.finish(), b.finish());
        // unaligned fallback
        let mut c = BitWriter::new();
        c.put_bit(1);
        c.put_byte(0xFF);
        assert_eq!(c.finish(), vec![0b1111_1111, 0b1000_0000]);
    }

    #[test]
    fn carry_increments_last_byte() {
        let mut w = BitWriter::new();
        w.put_byte(0x7F);
        w.carry_into_last_byte();
        assert_eq!(w.finish(), vec![0x80]);
        // empty buffer: carry is absorbed (dropped sentinel)
        let mut w = BitWriter::new();
        w.carry_into_last_byte();
        assert!(w.finish().is_empty());
    }

    #[test]
    fn put_run_matches_individual_bits() {
        let mut a = BitWriter::new();
        let mut b = BitWriter::new();
        a.put_bits(0b101, 3);
        b.put_bits(0b101, 3);
        a.put_run(1, 75);
        for _ in 0..75 {
            b.put_bit(1);
        }
        a.put_run(0, 5);
        for _ in 0..5 {
            b.put_bit(0);
        }
        assert_eq!(a.finish(), b.finish());
    }
}
