//! Delta application: reconstruct a target container from a parent
//! container plus a `.dcbc` v3 delta segment — in batch ([`apply`]) or
//! incrementally as bytes arrive ([`StreamApplier`]).
//!
//! The apply rule (normative spec: `docs/FORMAT.md` §"Delta segments")
//! is the exact inverse of the encoder: `L_target = P + R`, where `P`
//! quantizes the parent's reconstruction onto the delta layer's grid
//! and `R` is the delta's residual levels. The applied layer carries
//! the delta layer's header fields verbatim and a payload re-encoded
//! from `L_target` with the same codec config and chunk split, so the
//! output container is **byte-for-byte** the target the delta was
//! encoded from (`delta_roundtrip_is_byte_exact`).
//!
//! The per-layer apply rule lives in [`crate::delta::residual`], shared
//! with v4 progressive materialization; this module owns the v3 segment
//! checks (parent fingerprint) and the streaming applier.

use crate::delta::residual::{apply_layers, grid_reconstruct, parent_levels_on};
use crate::model::container::fingerprint;
use crate::model::{CompressedModel, DeltaModel};
use crate::serve::stream::{DecodedLayer, StreamDecoder, StreamEvent};
use anyhow::{bail, Result};

/// Reconstruct the target container from `parent` + `delta`.
///
/// Rejects (never panics) on: parent fingerprint mismatch (a stale or
/// wrong base — serve maps this to HTTP 409), layer count mismatch,
/// layer name mismatch, weight count mismatch, short residual decode,
/// and `P + R` overflowing `i32`.
pub fn apply(
    parent: &CompressedModel,
    delta: &DeltaModel,
    workers: usize,
) -> Result<CompressedModel> {
    let fp = fingerprint(parent);
    if fp != delta.parent_fp {
        crate::fuzz::cov::edge!("apply_fp_mismatch");
        bail!(
            "delta apply: parent fingerprint mismatch (delta expects {:016x}, \
             base is {:016x})",
            delta.parent_fp,
            fp
        );
    }
    crate::fuzz::cov::edge!("apply_ok");
    apply_layers(parent, &delta.layers, &delta.name, workers)
}

/// Incremental delta application on top of [`StreamDecoder`]: feed the
/// delta segment's bytes as they arrive and receive fully applied
/// layers (reconstructed target weights + bias) without waiting for
/// the whole transfer — the engine behind `deepcabac fetch --from`.
///
/// Emitted [`DecodedLayer`]s have `levels` = the **target's** levels
/// (`P + R`, not the residual) and `weights` = their dequantization;
/// `skipped` is preserved from the wire so callers can tell which
/// layers were carried over from the base unchanged.
pub struct StreamApplier<'a> {
    parent: &'a CompressedModel,
    parent_fp: u64,
    workers: usize,
    dec: StreamDecoder,
    started: bool,
}

impl<'a> StreamApplier<'a> {
    /// The parent fingerprint is computed once here (it hashes the full
    /// canonical serialization of `parent`).
    pub fn new(parent: &'a CompressedModel, workers: usize) -> Self {
        Self {
            parent,
            parent_fp: fingerprint(parent),
            workers,
            dec: StreamDecoder::new(),
            started: false,
        }
    }

    /// Feed a slice of delta-segment bytes; returns every layer fully
    /// applied by those bytes (possibly none). Errors are terminal.
    pub fn feed(&mut self, bytes: &[u8]) -> Result<Vec<DecodedLayer>> {
        let events = self.dec.feed(bytes)?;
        let mut out = Vec::new();
        for ev in events {
            match ev {
                StreamEvent::Start { version, n_layers, parent_fp, .. } => {
                    if version != crate::model::container::VERSION_DELTA {
                        crate::fuzz::cov::edge!("sapply_not_delta");
                        bail!(
                            "stream apply: container is version {version}, \
                             not a delta segment — fetch it without --from"
                        );
                    }
                    match parent_fp {
                        Some(fp) if fp == self.parent_fp => {}
                        Some(fp) => {
                            crate::fuzz::cov::edge!("sapply_fp_mismatch");
                            bail!(
                                "stream apply: parent fingerprint mismatch \
                                 (delta expects {fp:016x}, base is {:016x})",
                                self.parent_fp
                            )
                        }
                        None => bail!("stream apply: v3 prelude missing parent fingerprint"),
                    }
                    if n_layers != self.parent.layers.len() {
                        crate::fuzz::cov::edge!("sapply_layer_count");
                        bail!(
                            "stream apply: parent has {} layers, delta {}",
                            self.parent.layers.len(),
                            n_layers
                        );
                    }
                    self.started = true;
                }
                StreamEvent::Layer(l) => out.push(self.apply_streamed(*l)?),
                // Tier events only occur in v4 streams, which the Start
                // version check above already rejected
                StreamEvent::Chunk { .. } | StreamEvent::Tier { .. } | StreamEvent::End => {}
            }
        }
        Ok(out)
    }

    /// Verify the stream ended cleanly (all layers applied, no trailing
    /// bytes). Call after the last `feed`.
    pub fn finish(&self) -> Result<()> {
        self.dec.finish()?;
        if !self.started {
            bail!("stream apply: empty stream");
        }
        Ok(())
    }

    fn apply_streamed(&self, l: DecodedLayer) -> Result<DecodedLayer> {
        let pl = match self.parent.layers.get(l.index) {
            Some(pl) => pl,
            None => bail!("stream apply: delta has more layers than parent"),
        };
        if pl.name != l.name {
            crate::fuzz::cov::edge!("sapply_name_mismatch");
            bail!(
                "stream apply: layer name mismatch ({:?} vs {:?})",
                pl.name,
                l.name
            );
        }
        if l.skipped {
            // carried over from the base: reconstruct from the parent
            crate::fuzz::cov::edge!("sapply_skip");
            return Ok(DecodedLayer {
                index: l.index,
                name: pl.name.clone(),
                dims: pl.dims.clone(),
                grid: pl.grid,
                s_param: pl.s_param,
                n_weights: pl.n_weights,
                levels: pl.decode_levels_with(self.workers),
                weights: grid_reconstruct(pl, self.workers),
                bias: pl.bias.clone(),
                skipped: true,
            });
        }
        if pl.n_weights != l.n_weights {
            crate::fuzz::cov::edge!("sapply_weight_count");
            bail!(
                "stream apply: layer {:?} weight count mismatch ({} vs {})",
                l.name,
                pl.n_weights,
                l.n_weights
            );
        }
        let p = parent_levels_on(pl, &l.grid, self.workers);
        let mut levels = Vec::with_capacity(l.levels.len());
        for (&q, &r) in p.iter().zip(&l.levels) {
            let t = i32::try_from(q as i64 + r as i64).map_err(|_| {
                crate::fuzz::cov::edge!("sapply_overflow");
                anyhow::anyhow!("level overflow applying layer {:?}", l.name)
            })?;
            levels.push(t);
        }
        let weights = l.grid.dequantize(&levels);
        Ok(DecodedLayer { levels, weights, skipped: false, ..l })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::CodecConfig;
    use crate::delta::encode::encode;
    use crate::delta::residual::encode_with_splits;
    use crate::model::{CompressedLayer, DeltaLayer};
    use crate::quant::QuantGrid;
    use crate::util::SplitMix64;

    /// Build a layer directly from levels (grid Δ=0.25) with an optional
    /// chunk split, mirroring the container-test helpers.
    fn layer_from_levels(name: &str, levels: &[i32], n_chunks: usize) -> CompressedLayer {
        let cfg = CodecConfig::default();
        let max_level = levels.iter().map(|l| l.unsigned_abs()).max().unwrap_or(0) as i32;
        let splits: Vec<usize> = if n_chunks <= 1 {
            vec![levels.len()]
        } else {
            let per = (levels.len() + n_chunks - 1) / n_chunks;
            levels.chunks(per.max(1)).map(|c| c.len()).collect()
        };
        let (payload, chunks) = encode_with_splits(levels, cfg, &splits);
        CompressedLayer {
            name: name.into(),
            dims: vec![levels.len().max(1)],
            grid: QuantGrid { delta: 0.25, max_level: max_level.max(1) },
            s_param: 40,
            cfg,
            n_weights: levels.len(),
            payload,
            chunks,
            bias: vec![0.125, -0.5],
        }
    }

    fn random_levels(rng: &mut SplitMix64, n: usize, max: i32) -> Vec<i32> {
        (0..n)
            .map(|_| {
                if rng.next_f64() < 0.85 {
                    0
                } else {
                    let m = 1 + rng.below(max.max(1) as u64) as i32;
                    if rng.next_u64() & 1 == 0 { m } else { -m }
                }
            })
            .collect()
    }

    /// Parent/target pair: same architecture, target = parent with a
    /// sparse perturbation of the levels plus one untouched layer.
    fn parent_target_pair(seed: u64, n_chunks: usize) -> (CompressedModel, CompressedModel) {
        let mut rng = SplitMix64::new(seed);
        let base_a = random_levels(&mut rng, 600, 9);
        let base_b = random_levels(&mut rng, 257, 5);
        let mut upd_a = base_a.clone();
        for _ in 0..12 {
            let i = rng.below(upd_a.len() as u64) as usize;
            upd_a[i] += if rng.next_u64() & 1 == 0 { 1 } else { -1 };
        }
        let parent = CompressedModel {
            name: "m".into(),
            layers: vec![
                layer_from_levels("conv1", &base_a, n_chunks),
                layer_from_levels("fc", &base_b, 1),
            ],
        };
        let target = CompressedModel {
            name: "m".into(),
            layers: vec![
                layer_from_levels("conv1", &upd_a, n_chunks),
                layer_from_levels("fc", &base_b, 1),
            ],
        };
        (parent, target)
    }

    #[test]
    fn delta_roundtrip_is_byte_exact() {
        // apply(parent, encode(parent, target)) == target, byte for byte,
        // independent of worker count on either side — monolithic and
        // chunked layers alike.
        for (seed, n_chunks) in [(11u64, 1usize), (12, 3), (13, 4)] {
            let (parent, target) = parent_target_pair(seed, n_chunks);
            let (delta, report) = encode(&parent, &target, 1).unwrap();
            // the untouched layer became a skip record
            assert!(matches!(delta.layers[1], DeltaLayer::Skipped(_)));
            assert!(report.layers[1].skipped);
            // delta survives its own serialization
            let delta = DeltaModel::deserialize(&delta.serialize()).unwrap();
            let target_bytes = target.serialize();
            for workers in [1usize, 2, 4] {
                let applied = apply(&parent, &delta, workers).unwrap();
                assert_eq!(
                    applied.serialize(),
                    target_bytes,
                    "seed={seed} chunks={n_chunks} workers={workers}"
                );
            }
            // encoding with more workers produces the same delta bytes
            let (delta_par, _) = encode(&parent, &target, 4).unwrap();
            assert_eq!(delta_par.serialize(), delta.serialize());
        }
    }

    #[test]
    fn stream_apply_matches_batch_at_one_byte_dribble() {
        let (parent, target) = parent_target_pair(21, 3);
        let (delta, _) = encode(&parent, &target, 1).unwrap();
        let bytes = delta.serialize();
        let batch = apply(&parent, &delta, 1).unwrap();

        for split in [1usize, 7, bytes.len()] {
            let mut applier = StreamApplier::new(&parent, 2);
            let mut layers = Vec::new();
            for chunk in bytes.chunks(split) {
                layers.extend(applier.feed(chunk).unwrap());
            }
            applier.finish().unwrap();
            assert_eq!(layers.len(), batch.layers.len(), "split={split}");
            for (sl, bl) in layers.iter().zip(&batch.layers) {
                assert_eq!(sl.name, bl.name);
                assert_eq!(sl.levels, bl.decode_levels_with(1), "split={split}");
                assert_eq!(sl.weights, bl.decode_weights());
                assert_eq!(sl.bias, bl.bias);
            }
            // the skip record reconstructs from the parent
            assert!(layers[1].skipped);
            assert!(!layers[0].skipped);
        }
    }

    #[test]
    fn apply_rejects_wrong_parent() {
        let (parent, target) = parent_target_pair(31, 1);
        let (delta, _) = encode(&parent, &target, 1).unwrap();
        // a different base (the target itself) has a different fingerprint
        let err = apply(&target, &delta, 1).unwrap_err().to_string();
        assert!(err.contains("fingerprint mismatch"), "{err}");

        let mut applier = StreamApplier::new(&target, 1);
        let res = applier.feed(&delta.serialize());
        let err = res.unwrap_err().to_string();
        assert!(err.contains("fingerprint mismatch"), "{err}");
    }

    #[test]
    fn apply_rejects_structural_mismatches() {
        let (parent, target) = parent_target_pair(41, 1);
        let (mut delta, _) = encode(&parent, &target, 1).unwrap();

        // renamed skip record
        delta.layers[1] = DeltaLayer::Skipped("not_fc".into());
        let err = apply(&parent, &delta, 1).unwrap_err().to_string();
        assert!(err.contains("name mismatch"), "{err}");

        // layer-count lie
        let (mut delta, _) = encode(&parent, &target, 1).unwrap();
        delta.layers.pop();
        let err = apply(&parent, &delta, 1).unwrap_err().to_string();
        assert!(err.contains("layers"), "{err}");

        // weight-count lie on a coded layer
        let (mut delta, _) = encode(&parent, &target, 1).unwrap();
        if let DeltaLayer::Coded(c) = &mut delta.layers[0] {
            c.n_weights += 1;
        }
        assert!(apply(&parent, &delta, 1).is_err());

        // stream apply refuses a full (v1/v2) container fed as a delta
        let mut applier = StreamApplier::new(&parent, 1);
        let err = applier.feed(&target.serialize()).unwrap_err().to_string();
        assert!(err.contains("not a delta segment"), "{err}");
    }

    #[test]
    fn identical_models_delta_is_all_skips() {
        let (parent, _) = parent_target_pair(51, 2);
        let (delta, report) = encode(&parent, &parent, 1).unwrap();
        assert_eq!(delta.coded_layers(), 0);
        assert_eq!(report.residual_density(), 0.0);
        assert_eq!(delta.payload_bytes(), 0);
        let applied = apply(&parent, &delta, 1).unwrap();
        assert_eq!(applied.serialize(), parent.serialize());
        // the delta is a fraction of the full container
        assert!(delta.total_bytes() < parent.total_bytes() / 4);
    }
}
