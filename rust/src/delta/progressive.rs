//! Progressive (v4) encoding and materialization, built on the shared
//! residual core ([`crate::delta::residual`]).
//!
//! A progressive container is a chain of standalone containers for one
//! model, coarsest first: tier 0 is stored whole (v2 layer layout) and
//! every tier t ≥ 1 stores only the residual of its levels against the
//! previous tier's reconstruction, using the v3 delta algebra with
//! *positional* parenthood (the parent is the previous tier of the same
//! file, so no fingerprint is carried). The normative invariant
//! (`docs/FORMAT.md` §"Progressive tiers"):
//! [`materialize`]`(p, t)` is **byte-identical** to the standalone
//! container the encoder was given for tier t.

use crate::delta::encode::ParentCtx;
use crate::delta::residual::{apply_layers, diff_model_layers, DeltaReport};
use crate::model::container::{MAX_TIERS, VERSION_PROGRESSIVE};
use crate::model::{CompressedModel, ProgressiveModel};
use crate::serve::stream::{DecodedLayer, StreamDecoder, StreamEvent};
use anyhow::{bail, Context, Result};

/// Chain-encode a sequence of standalone containers (coarsest first)
/// into one progressive container. `chain[0]` becomes the base tier;
/// every later container must share the model name and architecture
/// (same layer count, names, weight counts). Returns the container and
/// one encoder report per refinement tier.
pub fn encode_progressive(
    chain: &[CompressedModel],
    workers: usize,
) -> Result<(ProgressiveModel, Vec<DeltaReport>)> {
    let Some(first) = chain.first() else {
        bail!("progressive encode: empty tier chain");
    };
    if chain.len() > MAX_TIERS {
        bail!(
            "progressive encode: {} tiers exceeds MAX_TIERS ({MAX_TIERS})",
            chain.len()
        );
    }
    let mut refinements = Vec::with_capacity(chain.len() - 1);
    let mut reports = Vec::with_capacity(chain.len() - 1);
    let mut ctx = ParentCtx::new(first.clone(), workers);
    for (t, target) in chain.iter().enumerate().skip(1) {
        if target.name != first.name {
            bail!(
                "progressive encode: tier {t} is model {:?}, base is {:?}",
                target.name,
                first.name
            );
        }
        let (layers, report) = diff_model_layers(&ctx.parent, &ctx.recon, target, workers)
            .with_context(|| format!("progressive encode: refinement tier {t}"))?;
        refinements.push(layers);
        reports.push(report);
        if t + 1 < chain.len() {
            ctx = ParentCtx::new(target.clone(), workers);
        }
    }
    Ok((
        ProgressiveModel {
            name: first.name.clone(),
            base: first.layers.clone(),
            refinements,
        },
        reports,
    ))
}

/// Materialize the standalone container at `tier`: tier 0 is the base
/// verbatim; each refinement 1..=t applies on top of the previous
/// tier's result with the v3 apply rule. Byte-identical to the
/// container the refinement was encoded from, at every worker count.
pub fn materialize(
    p: &ProgressiveModel,
    tier: usize,
    workers: usize,
) -> Result<CompressedModel> {
    if tier >= p.n_tiers() {
        crate::fuzz::cov::edge!("mat_tier_range");
        bail!(
            "tier {tier} out of range: progressive container has {} tiers",
            p.n_tiers()
        );
    }
    let mut cur = CompressedModel { name: p.name.clone(), layers: p.base.clone() };
    for (t, refinement) in p.refinements[..tier].iter().enumerate() {
        cur = apply_layers(&cur, refinement, &p.name, workers)
            .with_context(|| format!("materializing refinement tier {}", t + 1))?;
    }
    Ok(cur)
}

/// A usable model at a tier boundary: the fully refined state of every
/// layer after tiers `0..=tier` have been applied.
#[derive(Debug, Clone)]
pub struct TierSnapshot {
    pub tier: usize,
    pub n_tiers: usize,
    pub layers: Vec<DecodedLayer>,
}

/// Incremental progressive application on top of [`StreamDecoder`]:
/// feed v4 container bytes as they arrive and receive a usable model
/// ([`TierSnapshot`]) at **every tier boundary** — the base tier the
/// moment its last layer lands, then each refinement applied in place.
/// The engine behind `deepcabac fetch --tier`.
///
/// Emitted snapshots carry target levels and weights (residuals already
/// applied), mirroring [`crate::delta::StreamApplier`]; byte-exact
/// container materialization is the batch path ([`materialize`]).
pub struct ProgressiveApplier {
    workers: usize,
    dec: StreamDecoder,
    started: bool,
    /// Tier currently being filled (0 = base).
    tier: usize,
    /// Materialized per-layer state, updated in place by refinements.
    layers: Vec<DecodedLayer>,
}

impl ProgressiveApplier {
    pub fn new(workers: usize) -> Self {
        Self {
            workers,
            dec: StreamDecoder::new(),
            started: false,
            tier: 0,
            layers: Vec::new(),
        }
    }

    /// Feed a slice of container bytes; returns a snapshot for every
    /// tier those bytes completed (possibly none). Errors are terminal.
    pub fn feed(&mut self, bytes: &[u8]) -> Result<Vec<TierSnapshot>> {
        let events = self.dec.feed(bytes)?;
        let mut out = Vec::new();
        for ev in events {
            match ev {
                StreamEvent::Start { version, .. } => {
                    if version != VERSION_PROGRESSIVE {
                        crate::fuzz::cov::edge!("papply_not_v4");
                        bail!(
                            "progressive apply: container is version {version}, \
                             not progressive — fetch it without --tier"
                        );
                    }
                    self.started = true;
                }
                StreamEvent::Layer(l) => self.absorb(*l)?,
                StreamEvent::Tier { tier, n_tiers } => {
                    crate::fuzz::cov::edge!("papply_tier");
                    out.push(TierSnapshot {
                        tier,
                        n_tiers,
                        layers: self.layers.clone(),
                    });
                    self.tier = tier + 1;
                }
                StreamEvent::Chunk { .. } | StreamEvent::End => {}
            }
        }
        Ok(out)
    }

    /// Verify the stream ended at a tier boundary (or the declared end)
    /// with no trailing bytes. Returns the number of complete tiers.
    /// Call after the last `feed`.
    pub fn finish(&self) -> Result<usize> {
        self.dec.finish()?;
        if !self.started {
            bail!("progressive apply: empty stream");
        }
        Ok(self.tier)
    }

    fn absorb(&mut self, l: DecodedLayer) -> Result<()> {
        if self.tier == 0 {
            // base tier: layers arrive fully coded
            self.layers.push(l);
            return Ok(());
        }
        let cur = match self.layers.get_mut(l.index) {
            Some(cur) => cur,
            None => {
                crate::fuzz::cov::edge!("papply_extra_layer");
                bail!("progressive apply: refinement has more layers than base")
            }
        };
        if cur.name != l.name {
            crate::fuzz::cov::edge!("papply_name_mismatch");
            bail!(
                "progressive apply: layer name mismatch ({:?} vs {:?})",
                cur.name,
                l.name
            );
        }
        if l.skipped {
            // carried over: previous tier's layer stays current
            crate::fuzz::cov::edge!("papply_skip");
            return Ok(());
        }
        if cur.n_weights != l.n_weights {
            crate::fuzz::cov::edge!("papply_weight_count");
            bail!(
                "progressive apply: layer {:?} weight count mismatch ({} vs {})",
                l.name,
                cur.n_weights,
                l.n_weights
            );
        }
        // rescale rule: quantize the previous tier's reconstruction onto
        // the finer grid, then L = P + R
        let mut levels = Vec::with_capacity(l.levels.len());
        for (&w, &r) in cur.weights.iter().zip(&l.levels) {
            let q = l.grid.nearest_level(w);
            let t = i32::try_from(q as i64 + r as i64).map_err(|_| {
                crate::fuzz::cov::edge!("papply_overflow");
                anyhow::anyhow!("level overflow applying layer {:?}", l.name)
            })?;
            levels.push(t);
        }
        let weights = l.grid.dequantize(&levels);
        *cur = DecodedLayer { levels, weights, skipped: false, ..l };
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::CodecConfig;
    use crate::model::{CompressedLayer, Container};
    use crate::quant::QuantGrid;
    use crate::util::SplitMix64;

    /// Quantize one weight vector onto `grid`, producing the standalone
    /// layer a sweep point would emit (optionally chunked).
    fn layer_at(name: &str, w: &[f32], grid: QuantGrid, n_chunks: usize) -> CompressedLayer {
        let cfg = CodecConfig::default();
        let levels: Vec<i32> = w.iter().map(|&x| grid.nearest_level(x)).collect();
        let splits: Vec<usize> = if n_chunks <= 1 {
            vec![levels.len()]
        } else {
            let per = (levels.len() + n_chunks - 1) / n_chunks;
            levels.chunks(per.max(1)).map(|c| c.len()).collect()
        };
        let (payload, chunks) =
            crate::delta::residual::encode_with_splits(&levels, cfg, &splits);
        CompressedLayer {
            name: name.into(),
            dims: vec![w.len().max(1)],
            grid,
            s_param: 40,
            cfg,
            n_weights: w.len(),
            payload,
            chunks,
            bias: vec![0.25, -0.75],
        }
    }

    /// A chain of standalone containers at coarse → fine grids over the
    /// same weights, as `sweep --progressive` would pick off the
    /// frontier. The second layer's grid never changes, so refinement
    /// tiers should skip it.
    fn tier_chain(seed: u64, n_chunks: usize) -> Vec<CompressedModel> {
        let mut rng = SplitMix64::new(seed);
        let w_a: Vec<f32> =
            (0..500).map(|_| (rng.next_f64() * 2.0 - 1.0) as f32).collect();
        let w_b: Vec<f32> =
            (0..203).map(|_| (rng.next_f64() * 0.5 - 0.25) as f32).collect();
        let grids = [
            QuantGrid { delta: 0.25, max_level: 4 },
            QuantGrid { delta: 0.125, max_level: 8 },
            QuantGrid { delta: 0.0625, max_level: 16 },
        ];
        let fixed = QuantGrid { delta: 0.125, max_level: 2 };
        grids
            .iter()
            .map(|&g| CompressedModel {
                name: "prog".into(),
                layers: vec![
                    layer_at("conv1", &w_a, g, n_chunks),
                    layer_at("fc", &w_b, fixed, 1),
                ],
            })
            .collect()
    }

    #[test]
    fn materialize_is_byte_identical_to_standalone_tiers() {
        // the core v4 acceptance criterion: for every tier t,
        // materialize(base, R_1..R_t) == the standalone container at
        // tier t, byte for byte, across worker counts on both sides
        for (seed, n_chunks) in [(7u64, 1usize), (8, 3)] {
            let chain = tier_chain(seed, n_chunks);
            let (prog, reports) = encode_progressive(&chain, 1).unwrap();
            assert_eq!(prog.n_tiers(), 3);
            assert_eq!(reports.len(), 2);
            // the unchanged fc layer became a skip record in every tier
            for r in &prog.refinements {
                assert!(matches!(r[1], crate::model::DeltaLayer::Skipped(_)));
            }
            // survive the v4 wire round trip first
            let bytes = prog.serialize();
            let prog = match crate::model::deserialize_any(&bytes).unwrap() {
                Container::Progressive(p) => p,
                other => panic!("expected progressive, got {other:?}"),
            };
            for (t, standalone) in chain.iter().enumerate() {
                let want = standalone.serialize();
                for workers in [1usize, 2, 4] {
                    let got = materialize(&prog, t, workers).unwrap();
                    assert_eq!(
                        got.serialize(),
                        want,
                        "seed={seed} chunks={n_chunks} tier={t} workers={workers}"
                    );
                }
            }
            // encoding with more workers produces the same container bytes
            let (prog_par, _) = encode_progressive(&chain, 4).unwrap();
            assert_eq!(prog_par.serialize(), bytes);
        }
    }

    #[test]
    fn materialize_rejects_out_of_range_tier() {
        let chain = tier_chain(9, 1);
        let (prog, _) = encode_progressive(&chain, 1).unwrap();
        let err = materialize(&prog, 3, 1).unwrap_err().to_string();
        assert!(err.contains("out of range"), "{err}");
        assert!(err.contains("3 tiers"), "{err}");
    }

    #[test]
    fn encode_rejects_mismatched_chains() {
        let err = encode_progressive(&[], 1).unwrap_err().to_string();
        assert!(err.contains("empty tier chain"), "{err}");

        let mut chain = tier_chain(10, 1);
        chain[1].name = "other".into();
        let err = encode_progressive(&chain, 1).unwrap_err().to_string();
        assert!(err.contains("model"), "{err}");

        let mut chain = tier_chain(11, 1);
        chain[2].layers.pop();
        let err = encode_progressive(&chain, 1).unwrap_err().to_string();
        assert!(err.contains("layers"), "{err}");
    }

    #[test]
    fn streaming_applier_matches_batch_materialize_at_any_granularity() {
        let chain = tier_chain(12, 3);
        let (prog, _) = encode_progressive(&chain, 1).unwrap();
        let bytes = prog.serialize();
        // batch reference: materialized weights at each tier
        let batch: Vec<CompressedModel> =
            (0..3).map(|t| materialize(&prog, t, 1).unwrap()).collect();

        for split in [1usize, 7, 64, bytes.len()] {
            let mut applier = ProgressiveApplier::new(2);
            let mut snaps = Vec::new();
            for chunk in bytes.chunks(split) {
                snaps.extend(applier.feed(chunk).unwrap());
            }
            assert_eq!(applier.finish().unwrap(), 3, "split={split}");
            assert_eq!(snaps.len(), 3, "split={split}");
            for (snap, want) in snaps.iter().zip(&batch) {
                assert_eq!(snap.n_tiers, 3);
                assert_eq!(snap.layers.len(), want.layers.len());
                for (sl, wl) in snap.layers.iter().zip(&want.layers) {
                    assert_eq!(sl.name, wl.name);
                    assert_eq!(
                        sl.levels,
                        wl.decode_levels_with(1),
                        "split={split} tier={} layer={}",
                        snap.tier,
                        wl.name
                    );
                    assert_eq!(sl.weights, wl.decode_weights());
                    assert_eq!(sl.bias, wl.bias);
                }
            }
        }
    }

    #[test]
    fn streaming_applier_accepts_truncation_at_tier_boundary() {
        let chain = tier_chain(13, 1);
        let (prog, _) = encode_progressive(&chain, 1).unwrap();
        let bytes = prog.serialize();
        let lens = prog.tier_body_lens();
        let prelude = bytes.len() - lens.iter().sum::<usize>();
        // cut after tier 1's body: two usable tiers, clean finish
        let cut = prelude + lens[0] + lens[1];
        let mut applier = ProgressiveApplier::new(1);
        let snaps = applier.feed(&bytes[..cut]).unwrap();
        assert_eq!(snaps.len(), 2);
        assert_eq!(applier.finish().unwrap(), 2);
        // mid-tier cut: feed succeeds (waiting for more) but finish fails
        let mut applier = ProgressiveApplier::new(1);
        applier.feed(&bytes[..cut + 1]).unwrap();
        assert!(applier.finish().is_err());
    }

    #[test]
    fn applier_rejects_non_progressive_containers() {
        let chain = tier_chain(14, 1);
        let mut applier = ProgressiveApplier::new(1);
        let err = applier.feed(&chain[0].serialize()).unwrap_err().to_string();
        assert!(err.contains("not progressive"), "{err}");
    }
}
