//! The residual codec core: one implementation of level-space residual
//! coding shared by cross-file v3 delta segments (`delta/encode.rs`,
//! `delta/apply.rs`) and intra-file v4 progressive tier refinement
//! (`delta/progressive.rs`).
//!
//! Both schemes are the same algebra (`docs/FORMAT.md` §"Delta
//! segments" / §"Progressive tiers"): quantize the parent
//! reconstruction onto the target grid
//! (`P_i = clamp(round(wp_i/Δ), ±max_level)`), code `R = L_target − P`
//! with the target's codec config and chunk split, apply with
//! `L_target = P + R` re-encoded the same way — which makes the round
//! trip byte-exact because CABAC encoding is deterministic. What
//! differs is only the framing: a v3 segment names its parent by
//! fingerprint across files, a v4 refinement tier's parent is the
//! previous tier of the same file.

use crate::model::{ChunkInfo, CompressedLayer, CompressedModel, DeltaLayer};
use crate::quant::QuantGrid;
use anyhow::{bail, Result};

/// Per-layer accounting for reports and `BENCH_delta.json` /
/// `BENCH_progressive.json`.
#[derive(Debug, Clone)]
pub struct DeltaLayerReport {
    pub name: String,
    pub skipped: bool,
    /// Non-zero residual levels (0 for skipped layers).
    pub residual_nonzero: usize,
    pub n_weights: usize,
    /// Residual CABAC payload bytes (0 for skipped layers).
    pub delta_payload: usize,
    /// The target layer's payload bytes, for the ratio.
    pub target_payload: usize,
}

/// Encoder-side accounting returned alongside a coded residual model.
#[derive(Debug, Clone, Default)]
pub struct DeltaReport {
    pub layers: Vec<DeltaLayerReport>,
}

impl DeltaReport {
    /// Residual density across coded layers: non-zero residual levels
    /// over total weights.
    pub fn residual_density(&self) -> f64 {
        let nz: usize = self.layers.iter().map(|l| l.residual_nonzero).sum();
        let n: usize = self.layers.iter().map(|l| l.n_weights).sum();
        nz as f64 / n.max(1) as f64
    }
}

/// Two compressed layers are identical in every serialized field.
pub(crate) fn layers_equal(a: &CompressedLayer, b: &CompressedLayer) -> bool {
    a.name == b.name
        && a.dims == b.dims
        && a.grid.delta.to_bits() == b.grid.delta.to_bits()
        && a.grid.max_level == b.grid.max_level
        && a.s_param == b.s_param
        && a.cfg == b.cfg
        && a.n_weights == b.n_weights
        && a.payload == b.payload
        && a.chunks == b.chunks
        && a.bias.len() == b.bias.len()
        && a.bias.iter().zip(&b.bias).all(|(x, y)| x.to_bits() == y.to_bits())
}

/// Quantize a parent layer's reconstruction onto `grid` — the `P` of the
/// apply rule. Total and deterministic on any input (saturating casts;
/// non-finite quotients quantize to 0 via `round`/`clamp`).
pub(crate) fn parent_levels_on(
    parent: &CompressedLayer,
    grid: &QuantGrid,
    workers: usize,
) -> Vec<i32> {
    let wp = grid_reconstruct(parent, workers);
    wp.iter().map(|&w| grid.nearest_level(w)).collect()
}

/// The parent layer's reconstructed weights (levels × Δ), decoded with an
/// explicit worker cap so callers stay deterministic across parallelism.
pub(crate) fn grid_reconstruct(parent: &CompressedLayer, workers: usize) -> Vec<f32> {
    parent.grid.dequantize(&parent.decode_levels_with(workers))
}

/// Encode `levels` into chunk streams matching `splits` (per-chunk level
/// counts). A single split yields the canonical monolithic form.
pub(crate) fn encode_with_splits(
    levels: &[i32],
    cfg: crate::codec::CodecConfig,
    splits: &[usize],
) -> (Vec<u8>, Vec<ChunkInfo>) {
    if splits.len() <= 1 {
        return (crate::codec::encode_levels(levels, cfg), Vec::new());
    }
    let mut payload = Vec::new();
    let mut chunks = Vec::with_capacity(splits.len());
    let mut off = 0usize;
    for &n in splits {
        let bytes = crate::codec::encode_levels(&levels[off..off + n], cfg);
        chunks.push(ChunkInfo { n_weights: n, bytes: bytes.len() });
        payload.extend_from_slice(&bytes);
        off += n;
    }
    (payload, chunks)
}

/// Residual-code one target layer against the parent reconstruction
/// `wp` (the parent layer's dequantized weights). Returns the coded
/// residual layer (target header fields, residual payload) and the
/// number of non-zero residual levels.
pub(crate) fn diff_layer(
    wp: &[f32],
    tl: &CompressedLayer,
    workers: usize,
) -> Result<(CompressedLayer, usize)> {
    let p: Vec<i32> = wp.iter().map(|&w| tl.grid.nearest_level(w)).collect();
    let lt = tl.decode_levels_with(workers);
    if lt.len() != tl.n_weights {
        bail!("residual encode: target layer {:?} payload decodes short", tl.name);
    }
    let mut residual = Vec::with_capacity(lt.len());
    let mut nonzero = 0usize;
    for (&t, &q) in lt.iter().zip(&p) {
        let r = t as i64 - q as i64;
        let r = i32::try_from(r)
            .map_err(|_| anyhow::anyhow!("residual overflow in layer {:?}", tl.name))?;
        if r != 0 {
            nonzero += 1;
        }
        residual.push(r);
    }
    let splits: Vec<usize> = tl.chunk_spans().iter().map(|s| s.n_weights).collect();
    let (payload, chunks) = encode_with_splits(&residual, tl.cfg, &splits);
    Ok((
        CompressedLayer {
            name: tl.name.clone(),
            dims: tl.dims.clone(),
            grid: tl.grid,
            s_param: tl.s_param,
            cfg: tl.cfg,
            n_weights: tl.n_weights,
            payload,
            chunks,
            bias: tl.bias.clone(),
        },
        nonzero,
    ))
}

/// Residual-code every layer of `target` against `parent` (with the
/// parent reconstruction `recon` supplied, decoded once by the caller).
/// Byte-identical layers become skip records. This is the per-model
/// core both `delta::encode_with_ctx` (v3 segments) and
/// `delta::progressive::encode_progressive` (v4 tiers) wrap.
pub(crate) fn diff_model_layers(
    parent: &CompressedModel,
    recon: &[Vec<f32>],
    target: &CompressedModel,
    workers: usize,
) -> Result<(Vec<DeltaLayer>, DeltaReport)> {
    if parent.layers.len() != target.layers.len() {
        bail!(
            "delta encode: parent has {} layers, target {}",
            parent.layers.len(),
            target.layers.len()
        );
    }
    let mut layers = Vec::with_capacity(target.layers.len());
    let mut report = DeltaReport::default();
    for ((pl, tl), wp) in parent.layers.iter().zip(&target.layers).zip(recon) {
        if pl.name != tl.name {
            bail!("delta encode: layer name mismatch ({:?} vs {:?})", pl.name, tl.name);
        }
        if layers_equal(pl, tl) {
            report.layers.push(DeltaLayerReport {
                name: tl.name.clone(),
                skipped: true,
                residual_nonzero: 0,
                n_weights: tl.n_weights,
                delta_payload: 0,
                target_payload: tl.payload.len(),
            });
            layers.push(DeltaLayer::Skipped(tl.name.clone()));
            continue;
        }
        if pl.n_weights != tl.n_weights {
            bail!(
                "delta encode: layer {:?} weight count changed ({} vs {}) — \
                 deltas require a matching architecture",
                tl.name,
                pl.n_weights,
                tl.n_weights
            );
        }
        let (coded, nonzero) = diff_layer(wp, tl, workers)?;
        report.layers.push(DeltaLayerReport {
            name: tl.name.clone(),
            skipped: false,
            residual_nonzero: nonzero,
            n_weights: tl.n_weights,
            delta_payload: coded.payload.len(),
            target_payload: tl.payload.len(),
        });
        layers.push(DeltaLayer::Coded(coded));
    }
    Ok((layers, report))
}

/// Apply one coded residual layer against its parent layer: decode `R`,
/// rebuild `L = P + R`, re-encode with the residual layer's codec
/// config and chunk split so the result is byte-identical to the layer
/// the residual was coded from.
pub(crate) fn apply_layer(
    pl: &CompressedLayer,
    d: &CompressedLayer,
    workers: usize,
) -> Result<CompressedLayer> {
    if pl.n_weights != d.n_weights {
        crate::fuzz::cov::edge!("rapply_weight_count");
        bail!(
            "delta apply: layer {:?} weight count mismatch ({} vs {})",
            d.name,
            pl.n_weights,
            d.n_weights
        );
    }
    let residual = d.decode_levels_with(workers);
    if residual.len() != d.n_weights {
        crate::fuzz::cov::edge!("rapply_residual_short");
        bail!("delta apply: layer {:?} residual decodes short", d.name);
    }
    let target = target_levels(pl, d, &residual, workers)?;
    let splits: Vec<usize> = d.chunk_spans().iter().map(|s| s.n_weights).collect();
    let (payload, chunks) = encode_with_splits(&target, d.cfg, &splits);
    Ok(CompressedLayer {
        name: d.name.clone(),
        dims: d.dims.clone(),
        grid: d.grid,
        s_param: d.s_param,
        cfg: d.cfg,
        n_weights: d.n_weights,
        payload,
        chunks,
        bias: d.bias.clone(),
    })
}

/// `L_target = P + R` with overflow checked (a hostile delta can code
/// arbitrary residual magnitudes).
pub(crate) fn target_levels(
    pl: &CompressedLayer,
    d: &CompressedLayer,
    residual: &[i32],
    workers: usize,
) -> Result<Vec<i32>> {
    let p = parent_levels_on(pl, &d.grid, workers);
    let mut target = Vec::with_capacity(residual.len());
    for (&q, &r) in p.iter().zip(residual) {
        let t = i32::try_from(q as i64 + r as i64).map_err(|_| {
            crate::fuzz::cov::edge!("rapply_overflow");
            anyhow::anyhow!("level overflow applying layer {:?}", d.name)
        })?;
        target.push(t);
    }
    Ok(target)
}

/// Apply one residual refinement (a tier of dlayers) to a parent model,
/// with positional parenthood (no fingerprint — the caller vouches for
/// the parent, as v4 tiers do by construction). Shared by
/// [`crate::delta::apply`] (after its fingerprint check) and
/// [`crate::delta::progressive::materialize`].
pub(crate) fn apply_layers(
    parent: &CompressedModel,
    layers: &[DeltaLayer],
    name: &str,
    workers: usize,
) -> Result<CompressedModel> {
    if parent.layers.len() != layers.len() {
        crate::fuzz::cov::edge!("rapply_layer_count");
        bail!(
            "delta apply: parent has {} layers, delta {}",
            parent.layers.len(),
            layers.len()
        );
    }
    let mut out = Vec::with_capacity(layers.len());
    for (pl, dl) in parent.layers.iter().zip(layers) {
        if pl.name != dl.name() {
            crate::fuzz::cov::edge!("rapply_name_mismatch");
            bail!(
                "delta apply: layer name mismatch ({:?} vs {:?})",
                pl.name,
                dl.name()
            );
        }
        match dl {
            DeltaLayer::Skipped(_) => {
                crate::fuzz::cov::edge!("rapply_skip");
                out.push(pl.clone())
            }
            DeltaLayer::Coded(d) => {
                crate::fuzz::cov::edge!("rapply_coded");
                out.push(apply_layer(pl, d, workers)?)
            }
        }
    }
    Ok(CompressedModel { name: name.to_string(), layers: out })
}
