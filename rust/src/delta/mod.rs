//! Delta-model compression: a model as a base `.dcbc` container plus
//! CABAC-coded residual deltas (`.dcbc` v3 delta segments).
//!
//! The journal version of the paper ("A Universal Compression Algorithm
//! for DNNs", arXiv:1907.11900) extends the coder from weights to
//! weight-*update* residuals — the federated-learning and OTA-update
//! target. This module is that extension, built on the container format
//! in `docs/FORMAT.md` §"Delta segments (version 3)":
//!
//! * [`encode`] diffs a target container against a parent container in
//!   **level space**: per layer, the residual `R = L_target − P` where
//!   `P` quantizes the parent's reconstruction onto the target grid.
//!   Residuals of a sparse update are overwhelmingly zero, which the
//!   significance-flag contexts absorb.
//! * [`apply`] reverses it exactly: `L_target = P + R`, re-encoded with
//!   the same codec config and chunk split — so
//!   `apply(parent, encode(parent, target))` reproduces the target
//!   container **byte-for-byte** (see `delta_roundtrip_is_byte_exact`).
//! * [`StreamApplier`] applies a delta **in place as bytes arrive**, on
//!   top of [`crate::serve::stream::StreamDecoder`], for
//!   `deepcabac fetch --from`.
//! * [`encode_from_model`] compresses a raw target model first (through
//!   the standard pipeline) and then diffs — the entry point the
//!   delta-aware sweep (`coordinator::sweep::sweep_delta`) and the
//!   federated example build on.
//! * [`progressive`] applies the same residual algebra *within* one
//!   file: a `.dcbc` v4 progressive container chains quality tiers so
//!   that [`materialize`]`(p, t)` is byte-identical to the standalone
//!   container at tier t. The per-layer codec core both schemes share
//!   lives in [`residual`].

pub mod apply;
pub mod encode;
pub mod progressive;
pub(crate) mod residual;

pub use apply::{apply, StreamApplier};
pub use encode::{encode, encode_from_model, encode_with_ctx, DeltaReport, ParentCtx};
pub use progressive::{encode_progressive, materialize, ProgressiveApplier, TierSnapshot};
