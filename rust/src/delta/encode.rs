//! Delta encoding: diff a target container against a parent container
//! into a `.dcbc` v3 delta segment.
//!
//! The wire scheme (normative spec: `docs/FORMAT.md` §"Delta segments")
//! works in **level space** so the round trip is exact: per layer, the
//! parent's reconstruction is quantized onto the *target* grid
//! (`P_i = clamp(round(wp_i/Δ), ±max_level)`, [`QuantGrid::nearest_level`])
//! and the delta codes `R = L_target − P` with the target layer's codec
//! config and chunk split. Where a sparse update left weights untouched,
//! `L_target = P` and `R = 0` — the significance-flag contexts then code
//! the residual at a small fraction of the full container's rate.
//!
//! The per-layer residual machinery lives in [`crate::delta::residual`],
//! shared with v4 progressive tier refinement
//! ([`crate::delta::progressive`]); this module owns only the v3
//! segment framing (parent fingerprint, [`DeltaModel`]).
//!
//! [`QuantGrid::nearest_level`]: crate::quant::QuantGrid::nearest_level

use crate::coordinator::pipeline::{compress_model, CompressionSpec};
use crate::delta::residual::{diff_model_layers, grid_reconstruct};
use crate::model::container::fingerprint;
use crate::model::{CompressedModel, DeltaModel, Model};
use anyhow::Result;

pub use crate::delta::residual::{DeltaLayerReport, DeltaReport};

/// Parent-side state hoisted out of repeated [`encode`] calls against
/// one base — the delta-aware sweep encodes a delta per completed grid
/// point, and the parent's CABAC decode + fingerprint never change.
/// The progressive encoder reuses it per tier, chaining each tier's
/// output as the next tier's parent.
pub struct ParentCtx {
    pub parent: CompressedModel,
    pub fp: u64,
    /// Per-layer reconstruction (levels × Δ), decoded once.
    pub(crate) recon: Vec<Vec<f32>>,
}

impl ParentCtx {
    pub fn new(parent: CompressedModel, workers: usize) -> Self {
        let recon = parent.layers.iter().map(|l| grid_reconstruct(l, workers)).collect();
        let fp = fingerprint(&parent);
        Self { parent, fp, recon }
    }
}

/// Diff `target` against `parent`, producing a v3 delta segment that
/// [`crate::delta::apply`] turns back into `target` byte-for-byte.
///
/// Layer structure must match: same layer count, names, and weight
/// counts (a delta re-codes residuals, it does not re-architect).
/// Byte-identical layers become skip records.
pub fn encode(
    parent: &CompressedModel,
    target: &CompressedModel,
    workers: usize,
) -> Result<(DeltaModel, DeltaReport)> {
    encode_with_ctx(&ParentCtx::new(parent.clone(), workers), target, workers)
}

/// [`encode`] with the parent reconstruction and fingerprint supplied by
/// the caller (hoisted once per sweep).
pub fn encode_with_ctx(
    ctx: &ParentCtx,
    target: &CompressedModel,
    workers: usize,
) -> Result<(DeltaModel, DeltaReport)> {
    let (layers, report) = diff_model_layers(&ctx.parent, &ctx.recon, target, workers)?;
    Ok((
        DeltaModel { parent_fp: ctx.fp, name: target.name.clone(), layers },
        report,
    ))
}

/// Compress a raw target model through the standard pipeline, then diff
/// it against `parent`. Returns the full target container (what a
/// fresh client would download), the delta segment (what an up-to-date
/// client downloads), and the encoder report.
///
/// This is the encoder-side hostile-input boundary: non-finite weights,
/// biases, or sigmas are rejected here with a structured error before
/// they can poison the grid statistics (the never-panic guarantee the
/// encoder fuzz target enforces).
pub fn encode_from_model(
    parent: &CompressedModel,
    target: &Model,
    spec: &CompressionSpec,
    workers: usize,
) -> Result<(CompressedModel, DeltaModel, DeltaReport)> {
    for (i, t) in target.weights.iter().enumerate() {
        crate::tensor::validate_finite(&format!("target weights[{i}]"), &t.data)?;
    }
    for (i, t) in target.biases.iter().enumerate() {
        crate::tensor::validate_finite(&format!("target bias[{i}]"), &t.data)?;
    }
    for (i, t) in target.sigmas.iter().enumerate() {
        crate::tensor::validate_finite(&format!("target sigma[{i}]"), &t.data)?;
    }
    let (compressed, _report) = compress_model(target, spec, workers);
    let (delta, report) = encode(parent, &compressed, workers)?;
    Ok((compressed, delta, report))
}
