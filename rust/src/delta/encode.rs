//! Delta encoding: diff a target container against a parent container
//! into a `.dcbc` v3 delta segment.
//!
//! The wire scheme (normative spec: `docs/FORMAT.md` §"Delta segments")
//! works in **level space** so the round trip is exact: per layer, the
//! parent's reconstruction is quantized onto the *target* grid
//! (`P_i = clamp(round(wp_i/Δ), ±max_level)`, [`QuantGrid::nearest_level`])
//! and the delta codes `R = L_target − P` with the target layer's codec
//! config and chunk split. Where a sparse update left weights untouched,
//! `L_target = P` and `R = 0` — the significance-flag contexts then code
//! the residual at a small fraction of the full container's rate.

use crate::coordinator::pipeline::{compress_model, CompressionSpec};
use crate::model::container::fingerprint;
use crate::model::{
    ChunkInfo, CompressedLayer, CompressedModel, DeltaLayer, DeltaModel, Model,
};
use crate::quant::QuantGrid;
use anyhow::{bail, Result};

/// Per-layer accounting for reports and `BENCH_delta.json`.
#[derive(Debug, Clone)]
pub struct DeltaLayerReport {
    pub name: String,
    pub skipped: bool,
    /// Non-zero residual levels (0 for skipped layers).
    pub residual_nonzero: usize,
    pub n_weights: usize,
    /// Residual CABAC payload bytes (0 for skipped layers).
    pub delta_payload: usize,
    /// The target layer's payload bytes, for the ratio.
    pub target_payload: usize,
}

/// Encoder-side accounting returned alongside the [`DeltaModel`].
#[derive(Debug, Clone, Default)]
pub struct DeltaReport {
    pub layers: Vec<DeltaLayerReport>,
}

impl DeltaReport {
    /// Residual density across coded layers: non-zero residual levels
    /// over total weights.
    pub fn residual_density(&self) -> f64 {
        let nz: usize = self.layers.iter().map(|l| l.residual_nonzero).sum();
        let n: usize = self.layers.iter().map(|l| l.n_weights).sum();
        nz as f64 / n.max(1) as f64
    }
}

/// Two compressed layers are identical in every serialized field.
fn layers_equal(a: &CompressedLayer, b: &CompressedLayer) -> bool {
    a.name == b.name
        && a.dims == b.dims
        && a.grid.delta.to_bits() == b.grid.delta.to_bits()
        && a.grid.max_level == b.grid.max_level
        && a.s_param == b.s_param
        && a.cfg == b.cfg
        && a.n_weights == b.n_weights
        && a.payload == b.payload
        && a.chunks == b.chunks
        && a.bias.len() == b.bias.len()
        && a.bias.iter().zip(&b.bias).all(|(x, y)| x.to_bits() == y.to_bits())
}

/// Quantize a parent layer's reconstruction onto `grid` — the `P` of the
/// apply rule. Total and deterministic on any input (saturating casts;
/// non-finite quotients quantize to 0 via `round`/`clamp`).
pub(crate) fn parent_levels_on(
    parent: &CompressedLayer,
    grid: &QuantGrid,
    workers: usize,
) -> Vec<i32> {
    let wp = grid_reconstruct(parent, workers);
    wp.iter().map(|&w| grid.nearest_level(w)).collect()
}

/// The parent layer's reconstructed weights (levels × Δ), decoded with an
/// explicit worker cap so callers stay deterministic across parallelism.
pub(crate) fn grid_reconstruct(parent: &CompressedLayer, workers: usize) -> Vec<f32> {
    parent.grid.dequantize(&parent.decode_levels_with(workers))
}

/// Encode `levels` into chunk streams matching `splits` (per-chunk level
/// counts). A single split yields the canonical monolithic form.
pub(crate) fn encode_with_splits(
    levels: &[i32],
    cfg: crate::codec::CodecConfig,
    splits: &[usize],
) -> (Vec<u8>, Vec<ChunkInfo>) {
    if splits.len() <= 1 {
        return (crate::codec::encode_levels(levels, cfg), Vec::new());
    }
    let mut payload = Vec::new();
    let mut chunks = Vec::with_capacity(splits.len());
    let mut off = 0usize;
    for &n in splits {
        let bytes = crate::codec::encode_levels(&levels[off..off + n], cfg);
        chunks.push(ChunkInfo { n_weights: n, bytes: bytes.len() });
        payload.extend_from_slice(&bytes);
        off += n;
    }
    (payload, chunks)
}

/// Parent-side state hoisted out of repeated [`encode`] calls against
/// one base — the delta-aware sweep encodes a delta per completed grid
/// point, and the parent's CABAC decode + fingerprint never change.
pub struct ParentCtx {
    pub parent: CompressedModel,
    pub fp: u64,
    /// Per-layer reconstruction (levels × Δ), decoded once.
    recon: Vec<Vec<f32>>,
}

impl ParentCtx {
    pub fn new(parent: CompressedModel, workers: usize) -> Self {
        let recon = parent.layers.iter().map(|l| grid_reconstruct(l, workers)).collect();
        let fp = fingerprint(&parent);
        Self { parent, fp, recon }
    }
}

/// Diff `target` against `parent`, producing a v3 delta segment that
/// [`crate::delta::apply`] turns back into `target` byte-for-byte.
///
/// Layer structure must match: same layer count, names, and weight
/// counts (a delta re-codes residuals, it does not re-architect).
/// Byte-identical layers become skip records.
pub fn encode(
    parent: &CompressedModel,
    target: &CompressedModel,
    workers: usize,
) -> Result<(DeltaModel, DeltaReport)> {
    encode_with_ctx(&ParentCtx::new(parent.clone(), workers), target, workers)
}

/// [`encode`] with the parent reconstruction and fingerprint supplied by
/// the caller (hoisted once per sweep).
pub fn encode_with_ctx(
    ctx: &ParentCtx,
    target: &CompressedModel,
    workers: usize,
) -> Result<(DeltaModel, DeltaReport)> {
    let parent = &ctx.parent;
    if parent.layers.len() != target.layers.len() {
        bail!(
            "delta encode: parent has {} layers, target {}",
            parent.layers.len(),
            target.layers.len()
        );
    }
    let mut layers = Vec::with_capacity(target.layers.len());
    let mut report = DeltaReport::default();
    for ((pl, tl), wp) in parent.layers.iter().zip(&target.layers).zip(&ctx.recon) {
        if pl.name != tl.name {
            bail!("delta encode: layer name mismatch ({:?} vs {:?})", pl.name, tl.name);
        }
        if layers_equal(pl, tl) {
            report.layers.push(DeltaLayerReport {
                name: tl.name.clone(),
                skipped: true,
                residual_nonzero: 0,
                n_weights: tl.n_weights,
                delta_payload: 0,
                target_payload: tl.payload.len(),
            });
            layers.push(DeltaLayer::Skipped(tl.name.clone()));
            continue;
        }
        if pl.n_weights != tl.n_weights {
            bail!(
                "delta encode: layer {:?} weight count changed ({} vs {}) — \
                 deltas require a matching architecture",
                tl.name,
                pl.n_weights,
                tl.n_weights
            );
        }
        let p: Vec<i32> = wp.iter().map(|&w| tl.grid.nearest_level(w)).collect();
        let lt = tl.decode_levels_with(workers);
        if lt.len() != tl.n_weights {
            bail!("delta encode: target layer {:?} payload decodes short", tl.name);
        }
        let mut residual = Vec::with_capacity(lt.len());
        let mut nonzero = 0usize;
        for (&t, &q) in lt.iter().zip(&p) {
            let r = t as i64 - q as i64;
            let r = i32::try_from(r)
                .map_err(|_| anyhow::anyhow!("residual overflow in layer {:?}", tl.name))?;
            if r != 0 {
                nonzero += 1;
            }
            residual.push(r);
        }
        let splits: Vec<usize> = tl.chunk_spans().iter().map(|s| s.n_weights).collect();
        let (payload, chunks) = encode_with_splits(&residual, tl.cfg, &splits);
        report.layers.push(DeltaLayerReport {
            name: tl.name.clone(),
            skipped: false,
            residual_nonzero: nonzero,
            n_weights: tl.n_weights,
            delta_payload: payload.len(),
            target_payload: tl.payload.len(),
        });
        layers.push(DeltaLayer::Coded(CompressedLayer {
            name: tl.name.clone(),
            dims: tl.dims.clone(),
            grid: tl.grid,
            s_param: tl.s_param,
            cfg: tl.cfg,
            n_weights: tl.n_weights,
            payload,
            chunks,
            bias: tl.bias.clone(),
        }));
    }
    Ok((
        DeltaModel { parent_fp: ctx.fp, name: target.name.clone(), layers },
        report,
    ))
}

/// Compress a raw target model through the standard pipeline, then diff
/// it against `parent`. Returns the full target container (what a
/// fresh client would download), the delta segment (what an up-to-date
/// client downloads), and the encoder report.
///
/// This is the encoder-side hostile-input boundary: non-finite weights,
/// biases, or sigmas are rejected here with a structured error before
/// they can poison the grid statistics (the never-panic guarantee the
/// encoder fuzz target enforces).
pub fn encode_from_model(
    parent: &CompressedModel,
    target: &Model,
    spec: &CompressionSpec,
    workers: usize,
) -> Result<(CompressedModel, DeltaModel, DeltaReport)> {
    for (i, t) in target.weights.iter().enumerate() {
        crate::tensor::validate_finite(&format!("target weights[{i}]"), &t.data)?;
    }
    for (i, t) in target.biases.iter().enumerate() {
        crate::tensor::validate_finite(&format!("target bias[{i}]"), &t.data)?;
    }
    for (i, t) in target.sigmas.iter().enumerate() {
        crate::tensor::validate_finite(&format!("target sigma[{i}]"), &t.data)?;
    }
    let (compressed, _report) = compress_model(target, spec, workers);
    let (delta, report) = encode(parent, &compressed, workers)?;
    Ok((compressed, delta, report))
}
