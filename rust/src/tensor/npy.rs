//! Minimal `.npy` (NumPy v1.0/2.0 format) reader/writer.
//!
//! Supports C-contiguous little-endian `<f4`, `<i4` and `<i8` arrays —
//! exactly what `python/compile/aot.py` emits. Hand-rolled because
//! neither serde nor ndarray-npy are in the offline registry.

use anyhow::{anyhow, bail, Context, Result};
use byteorder::{ByteOrder, LittleEndian};
use std::io::Write;
use std::path::Path;

const MAGIC: &[u8; 6] = b"\x93NUMPY";

struct Header {
    descr: String,
    fortran: bool,
    shape: Vec<usize>,
    data_off: usize,
}

fn parse_header(buf: &[u8]) -> Result<Header> {
    if buf.len() < 10 || &buf[..6] != MAGIC {
        bail!("not an npy file");
    }
    let (major, _minor) = (buf[6], buf[7]);
    let (hlen, hstart) = match major {
        1 => (LittleEndian::read_u16(&buf[8..10]) as usize, 10),
        2 | 3 => {
            if buf.len() < 12 {
                bail!("truncated npy v2 preamble");
            }
            (LittleEndian::read_u32(&buf[8..12]) as usize, 12)
        }
        v => bail!("unsupported npy version {v}"),
    };
    if hstart + hlen > buf.len() {
        bail!("npy header length {hlen} exceeds file size");
    }
    let header = std::str::from_utf8(&buf[hstart..hstart + hlen])
        .context("npy header not utf8")?;

    // The header is a Python dict literal:
    // {'descr': '<f4', 'fortran_order': False, 'shape': (3, 4), }
    let descr = extract(header, "'descr':")
        .ok_or_else(|| anyhow!("no descr"))?
        .trim()
        .trim_matches(|c| c == '\'' || c == '"')
        .to_string();
    let fortran = extract(header, "'fortran_order':")
        .ok_or_else(|| anyhow!("no fortran_order"))?
        .trim()
        .starts_with("True");
    let shape_src = header
        .split("'shape':")
        .nth(1)
        .and_then(|rest| rest.split('(').nth(1))
        .and_then(|rest| rest.split(')').next())
        .ok_or_else(|| anyhow!("no shape"))?;
    let shape: Vec<usize> = shape_src
        .split(',')
        .map(|t| t.trim())
        .filter(|t| !t.is_empty())
        .map(|t| t.parse::<usize>().context("bad dim"))
        .collect::<Result<_>>()?;
    Ok(Header { descr, fortran, shape, data_off: hstart + hlen })
}

/// Value after `key` up to the next comma that is not inside parens.
fn extract<'a>(header: &'a str, key: &str) -> Option<&'a str> {
    let rest = header.split(key).nth(1)?;
    let mut depth = 0;
    for (i, c) in rest.char_indices() {
        match c {
            '(' => depth += 1,
            ')' => depth -= 1,
            ',' if depth == 0 => return Some(&rest[..i]),
            _ => {}
        }
    }
    Some(rest)
}

/// Read an `<f4` npy file into (shape, data).
pub fn read_npy_f32(path: &Path) -> Result<(Vec<usize>, Vec<f32>)> {
    let buf = std::fs::read(path).with_context(|| format!("reading {path:?}"))?;
    let h = parse_header(&buf)?;
    if h.fortran {
        bail!("fortran-order npy unsupported");
    }
    let n: usize = h.shape.iter().product();
    let body = &buf[h.data_off..];
    let need = |bytes: usize| -> Result<usize> {
        let want = n.checked_mul(bytes).context("npy shape overflow")?;
        if body.len() < want {
            bail!("truncated npy: want {want} bytes, have {}", body.len());
        }
        Ok(want)
    };
    match h.descr.as_str() {
        "<f4" => {
            let want = need(4)?;
            let mut out = vec![0f32; n];
            LittleEndian::read_f32_into(&body[..want], &mut out);
            Ok((h.shape, out))
        }
        "<f8" => {
            let want = need(8)?;
            let mut tmp = vec![0f64; n];
            LittleEndian::read_f64_into(&body[..want], &mut tmp);
            Ok((h.shape, tmp.into_iter().map(|v| v as f32).collect()))
        }
        d => bail!("expected float npy, got descr {d}"),
    }
}

/// Read an `<i4`/`<i8` npy file into (shape, data as i32).
pub fn read_npy_i32(path: &Path) -> Result<(Vec<usize>, Vec<i32>)> {
    let buf = std::fs::read(path).with_context(|| format!("reading {path:?}"))?;
    let h = parse_header(&buf)?;
    if h.fortran {
        bail!("fortran-order npy unsupported");
    }
    let n: usize = h.shape.iter().product();
    let body = &buf[h.data_off..];
    let need = |bytes: usize| -> Result<usize> {
        let want = n.checked_mul(bytes).context("npy shape overflow")?;
        if body.len() < want {
            bail!("truncated npy: want {want} bytes, have {}", body.len());
        }
        Ok(want)
    };
    match h.descr.as_str() {
        "<i4" => {
            let want = need(4)?;
            let mut out = vec![0i32; n];
            LittleEndian::read_i32_into(&body[..want], &mut out);
            Ok((h.shape, out))
        }
        "<i8" => {
            let want = need(8)?;
            let mut tmp = vec![0i64; n];
            LittleEndian::read_i64_into(&body[..want], &mut tmp);
            Ok((h.shape, tmp.into_iter().map(|v| v as i32).collect()))
        }
        d => bail!("expected int npy, got descr {d}"),
    }
}

/// Write an `<f4` npy v1.0 file.
pub fn write_npy_f32(path: &Path, shape: &[usize], data: &[f32]) -> Result<()> {
    assert_eq!(shape.iter().product::<usize>(), data.len());
    let shape_str = match shape.len() {
        0 => "()".to_string(),
        1 => format!("({},)", shape[0]),
        _ => format!(
            "({})",
            shape.iter().map(|d| d.to_string()).collect::<Vec<_>>().join(", ")
        ),
    };
    let mut header = format!(
        "{{'descr': '<f4', 'fortran_order': False, 'shape': {shape_str}, }}"
    );
    // pad to 64-byte alignment of the full preamble, newline-terminated
    let pre = 10;
    let total = ((pre + header.len() + 1 + 63) / 64) * 64;
    while pre + header.len() + 1 < total {
        header.push(' ');
    }
    header.push('\n');

    let mut f = std::fs::File::create(path)?;
    f.write_all(MAGIC)?;
    f.write_all(&[1, 0])?;
    f.write_all(&(header.len() as u16).to_le_bytes())?;
    f.write_all(header.as_bytes())?;
    let mut body = vec![0u8; data.len() * 4];
    LittleEndian::write_f32_into(data, &mut body);
    f.write_all(&body)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn write_read_roundtrip() {
        let dir = std::env::temp_dir().join("dcbc_npy_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.npy");
        let shape = vec![3usize, 4];
        let data: Vec<f32> = (0..12).map(|i| i as f32 * 0.5 - 2.0).collect();
        write_npy_f32(&p, &shape, &data).unwrap();
        let (s, d) = read_npy_f32(&p).unwrap();
        assert_eq!(s, shape);
        assert_eq!(d, data);
    }

    #[test]
    fn one_dim_and_scalar_shapes() {
        let dir = std::env::temp_dir().join("dcbc_npy_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("v.npy");
        write_npy_f32(&p, &[5], &[1.0, 2.0, 3.0, 4.0, 5.0]).unwrap();
        let (s, d) = read_npy_f32(&p).unwrap();
        assert_eq!(s, vec![5]);
        assert_eq!(d.len(), 5);
        assert_eq!(d[4], 5.0);
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join("dcbc_npy_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.npy");
        std::fs::write(&p, b"not an npy at all").unwrap();
        assert!(read_npy_f32(&p).is_err());
    }
}
