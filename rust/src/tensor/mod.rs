//! Row-major tensors + `.npy` interchange with the Python build path.

pub mod npy;

pub use npy::{read_npy_f32, read_npy_i32, write_npy_f32};

#[cfg(test)]
mod finite_tests {
    use super::validate_finite;

    #[test]
    fn accepts_finite_rejects_nan_and_inf() {
        assert!(validate_finite("w", &[0.0, -1.5, 3.0e30]).is_ok());
        assert!(validate_finite("w", &[]).is_ok());
        let err = validate_finite("layer \"fc1\" weights", &[0.0, f32::NAN])
            .unwrap_err()
            .to_string();
        assert!(err.contains("fc1"), "{err}");
        assert!(err.contains("[1]"), "{err}");
        assert!(err.contains("NaN"), "{err}");
        let err = validate_finite("sigma", &[f32::INFINITY]).unwrap_err().to_string();
        assert!(err.contains("[0]"), "{err}");
        let err =
            validate_finite("sigma", &[1.0, f32::NEG_INFINITY]).unwrap_err().to_string();
        assert!(err.contains("inf"), "{err}");
    }
}

/// Reject non-finite entries with an error naming the tensor and the
/// offending index. A NaN weight silently corrupts the RD scan (every
/// candidate cost becomes NaN, so the quantizer keeps its level-0
/// sentinel and reports distortion 0.0) and a NaN/Inf σ or weight
/// poisons the grid statistics of eq. 2 — so non-finite values are
/// rejected at load time instead of being quietly swallowed.
pub fn validate_finite(what: &str, data: &[f32]) -> anyhow::Result<()> {
    for (i, &v) in data.iter().enumerate() {
        if !v.is_finite() {
            anyhow::bail!(
                "{what}[{i}] is {v} — tensors must contain only finite values"
            );
        }
    }
    Ok(())
}

/// A row-major f32 tensor (all weight tensors in this crate are f32).
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Self { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        Self { shape, data: vec![0.0; n] }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Max |w| (0 for empty tensors).
    pub fn abs_max(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &v| m.max(v.abs()))
    }

    /// Fraction of non-zero entries.
    pub fn density(&self) -> f64 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data.iter().filter(|&&v| v != 0.0).count() as f64 / self.data.len() as f64
    }

    /// Bytes of the raw f32 representation (the "original size" of Table 1).
    pub fn raw_bytes(&self) -> usize {
        self.data.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats() {
        let t = Tensor::new(vec![2, 3], vec![0.0, -2.0, 1.0, 0.0, 0.0, 0.5]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.abs_max(), 2.0);
        assert!((t.density() - 0.5).abs() < 1e-12);
        assert_eq!(t.raw_bytes(), 24);
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn shape_mismatch_panics() {
        Tensor::new(vec![2, 2], vec![1.0]);
    }
}
