//! DeepCABAC CLI entry point — see `deepcabac --help` / [`deepcabac::cli::USAGE`].

use anyhow::{anyhow, bail, Context, Result};
use byteorder::ByteOrder as _;
use deepcabac::app;
use deepcabac::cli::{Args, USAGE};
use deepcabac::codec::{decode_levels, CodecConfig, LevelEncoder};
use deepcabac::coordinator::{
    compress_model, pipeline::decompress, sweep_delta, sweep_progressive, sweep_s,
    sweep_s_auto, CompressionSpec, ProgressiveSweep, SweepOptions, SweepResult,
};
use deepcabac::model::{deserialize_any, fingerprint, CompressedModel, Container, DeltaModel};
use deepcabac::report::{human_bytes, Table};
use deepcabac::runtime::Runtime;
use deepcabac::synth::Arch;
use deepcabac::tensor::npy;
use deepcabac::util::json::{self, Json};
use deepcabac::util::{fnv1a, Timer};

/// Metering allocator from the fuzz subsystem: installed by the CLI (not
/// the library) so `deepcabac fuzz` *enforces* per-case allocation
/// budgets instead of just reporting them as unmetered. Pass-through to
/// the system allocator plus two thread-local counters — negligible
/// overhead for every other subcommand.
#[global_allocator]
static ALLOC: deepcabac::fuzz::alloc::CountingAlloc = deepcabac::fuzz::alloc::CountingAlloc;

fn main() {
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() || argv[0] == "--help" || argv[0] == "help" {
        print!("{USAGE}");
        return;
    }
    // `delta` takes an action word (encode|apply|bench); fold it into
    // the command so the flag parser sees no positional argument
    if argv[0] == "delta" {
        if argv.len() < 2 || argv[1].starts_with("--") {
            eprintln!("error: delta needs an action: encode | apply | bench\n\n{USAGE}");
            std::process::exit(2);
        }
        let action = argv.remove(1);
        argv[0] = format!("delta-{action}");
    }
    let args = match Args::parse(&argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{USAGE}");
            std::process::exit(2);
        }
    };
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(args: &Args) -> Result<()> {
    match args.cmd.as_str() {
        "table1" => cmd_table1(args),
        "compress" => cmd_compress(args),
        "compress-npy" => cmd_compress_npy(args),
        "decompress" => cmd_decompress(args),
        "eval" => cmd_eval(args),
        "anatomy" => cmd_anatomy(args),
        "sweep" => cmd_sweep(args),
        "materialize" => cmd_materialize(args),
        "synth" => cmd_synth(args),
        "serve" => cmd_serve(args),
        "fetch" => cmd_fetch(args),
        "loadgen" => cmd_loadgen(args),
        "fuzz" => cmd_fuzz(args),
        "delta-encode" => cmd_delta_encode(args),
        "delta-apply" => cmd_delta_apply(args),
        "delta-bench" => cmd_delta_bench(args),
        other if other.starts_with("delta-") => {
            bail!("unknown delta action {:?} (encode | apply | bench)", &other[6..])
        }
        other => bail!("unknown subcommand {other:?}\n\n{USAGE}"),
    }
}

fn base_spec(args: &Args) -> Result<CompressionSpec> {
    let chunks = args.get_count("chunks", 1).map_err(|e| anyhow!(e))?;
    if chunks > deepcabac::model::container::MAX_CHUNKS {
        bail!("--chunks must be in 1..={}", deepcabac::model::container::MAX_CHUNKS);
    }
    Ok(CompressionSpec {
        lambda_scale: args.get_f32("lambda-scale", 0.05).map_err(|e| anyhow!(e))?,
        chunks: chunks as u32,
        ..Default::default()
    })
}

fn cmd_table1(args: &Args) -> Result<()> {
    let sweep_points = args.get_count("sweep", 17).map_err(|e| anyhow!(e))?;
    let workers = args.get_count("workers", 1).map_err(|e| anyhow!(e))?;
    let scale = args.get_usize("scale", 8).map_err(|e| anyhow!(e))?;
    let with_eval = !args.has("no-eval");
    let spec = base_spec(args)?;
    let s_grid = deepcabac::coordinator::sweep::default_s_grid(sweep_points);

    let mut table = Table::new(&[
        "Model", "Dataset", "Org.acc(top1)", "Org.size", "Spars.[%]",
        "Comp.ratio[%]", "Acc.after", "best S",
    ]);
    for name in app::SMALL_MODELS {
        eprintln!("[table1] {name} ...");
        let row = app::table1_small_row(name, &s_grid, &spec, workers, with_eval)?;
        table.row(vec![
            row.model.clone(),
            row.dataset.clone(),
            format!("{:.2}", row.org_metric * metric_scale(&row.model)),
            human_bytes(row.org_bytes),
            format!("{:.2}", row.sparsity_pct),
            format!("{:.2}", row.ratio_pct),
            row.metric_after
                .map(|m| format!("{:.2}", m * metric_scale(&row.model)))
                .unwrap_or_else(|| "n/a".into()),
            row.best_s.to_string(),
        ]);
    }
    if args.has("large") {
        for arch in [Arch::Vgg16, Arch::ResNet50, Arch::MobileNetV1] {
            eprintln!("[table1] {} (synthetic, 1/{scale} scale) ...", arch.name());
            let row =
                app::table1_large_row(arch, scale, &s_grid, &spec, workers, 42)?;
            table.row(vec![
                row.model.clone(),
                row.dataset.clone(),
                "n/a".into(),
                human_bytes(row.org_bytes),
                format!("{:.2}", row.sparsity_pct),
                format!("{:.2}", row.ratio_pct),
                "n/a".into(),
                row.best_s.to_string(),
            ]);
        }
    }
    println!("{}", table.render());
    Ok(())
}

/// classifiers report %, fcae reports PSNR dB
fn metric_scale(model: &str) -> f64 {
    if model == "fcae" {
        1.0
    } else {
        100.0
    }
}

fn cmd_compress(args: &Args) -> Result<()> {
    let name = args.get("model").context("--model required")?;
    let out = args.get("out").context("--out required")?;
    let workers = args.get_count("workers", 1).map_err(|e| anyhow!(e))?;
    let model = app::load_model(name)?;
    let mut spec = base_spec(args)?;
    let (compressed, report) = if let Some(s) = args.get("s") {
        spec.s = s.parse().context("--s expects an integer")?;
        compress_model(&model, &spec, workers)
    } else {
        let points = args.get_count("sweep", 17).map_err(|e| anyhow!(e))?;
        let grid = deepcabac::coordinator::sweep::default_s_grid(points);
        if args.has("per-layer") {
            let (c, r, chosen) = deepcabac::coordinator::sweep::sweep_s_per_layer(
                &model, &grid, &spec, workers,
            )?;
            for (l, s) in &chosen {
                eprintln!("  {l}: S = {s}");
            }
            (c, r)
        } else {
            sweep_s(&model, &grid, &spec, workers)?.best
        }
    };
    std::fs::write(out, compressed.serialize())?;
    println!(
        "{name}: {} -> {} ({:.2}% of original, x{:.1}) S={}{}",
        human_bytes(report.raw_bytes),
        human_bytes(report.compressed_bytes),
        report.ratio_percent(),
        report.factor(),
        compressed.layers.first().map(|l| l.s_param).unwrap_or(0),
        if compressed.is_chunked() {
            format!(" chunks={}", report.total_chunks())
        } else {
            String::new()
        },
    );
    Ok(())
}

/// Compress an arbitrary `.npy` weight tensor from disk (σ optional:
/// without it the unweighted η = 1 ablation path is used).
fn cmd_compress_npy(args: &Args) -> Result<()> {
    let input = std::path::PathBuf::from(args.get("in").context("--in required")?);
    let out = args.get("out").context("--out required")?;
    let (shape, data) = npy::read_npy_f32(&input)?;
    deepcabac::tensor::validate_finite(&format!("{input:?} weights"), &data)?;
    let (sigmas, weighted) = match args.get("sigma") {
        Some(p) => {
            let (ss, sd) = npy::read_npy_f32(std::path::Path::new(p))?;
            anyhow::ensure!(ss == shape, "sigma shape {ss:?} != weight shape {shape:?}");
            deepcabac::tensor::validate_finite(&format!("{p:?} sigma"), &sd)?;
            (sd, true)
        }
        None => (vec![0.05f32; data.len()], false),
    };
    let mut spec = base_spec(args)?;
    spec.weighted = weighted;
    spec.s = args.get_usize("s", 64).map_err(|e| anyhow!(e))? as u32;
    let name = input.file_stem().and_then(|s| s.to_str()).unwrap_or("tensor");
    let (layer, report) =
        deepcabac::coordinator::compress_tensor(name, &shape, &data, &sigmas, &[], &spec);
    let container = CompressedModel { name: name.into(), layers: vec![layer] };
    std::fs::write(out, container.serialize())?;
    println!(
        "{name}: {} -> {} ({:.3} bits/weight, density {:.2}%)",
        human_bytes(data.len() * 4),
        human_bytes(report.payload_bytes),
        report.bits_per_weight(),
        report.density() * 100.0,
    );
    Ok(())
}

fn cmd_decompress(args: &Args) -> Result<()> {
    let input = args.get("in").context("--in required")?;
    let out_dir = std::path::PathBuf::from(args.get("out-dir").context("--out-dir required")?);
    std::fs::create_dir_all(&out_dir)?;
    let bytes = std::fs::read(input)?;
    let compressed = CompressedModel::deserialize(&bytes)?;
    let tensors = decompress(&compressed);
    for (layer, t) in compressed.layers.iter().zip(&tensors) {
        let path = out_dir.join(format!("{}.w.npy", layer.name));
        npy::write_npy_f32(&path, &t.shape, &t.data)?;
        println!("wrote {path:?} {:?}", t.shape);
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let name = args.get("model").context("--model required")?;
    let model = app::load_model(name)?;
    let rt = Runtime::cpu()?;
    let result = if let Some(path) = args.get("compressed") {
        let compressed = CompressedModel::deserialize(&std::fs::read(path)?)?;
        app::evaluate_compressed(&rt, &model, &compressed)?
    } else {
        app::evaluate_original(&rt, &model)?
    };
    let unit = if model.manifest.task == "classify" { "top-1" } else { "PSNR dB" };
    println!(
        "{name}: {:.4} {unit} over {} samples ({:.2}s on {})",
        result.metric,
        result.n_samples,
        result.exec_time_s,
        rt.platform(),
    );
    Ok(())
}

fn cmd_anatomy(args: &Args) -> Result<()> {
    let levels: Vec<i32> = args
        .get_or("levels", "0,3,0,0,-1,14,0,1")
        .split(',')
        .map(|t| t.trim().parse::<i32>().context("bad level"))
        .collect::<Result<_>>()?;
    println!("DeepCABAC binarization trace (paper figure 1)\n");
    let cfg = CodecConfig::default();
    let mut enc = LevelEncoder::new(cfg);
    println!("{:<8} {:<28} {}", "level", "bins (sig/sign/gr../rem)", "ctx p(sig=1) before");
    for &l in &levels {
        let p_sig = enc.ctxs.sig
            [deepcabac::codec::ContextSet::sig_ctx_index(&cfg, enc.prev_sig())]
        .p_one();
        println!("{:<8} {:<28} {:.3}", l, describe_bins(l, &cfg), p_sig);
        enc.encode_level(l);
    }
    let n = levels.len();
    let payload = enc.finish();
    println!(
        "\n{} levels -> {} bytes ({:.2} bits/level); raw f32 would be {} bytes",
        n,
        payload.len(),
        payload.len() as f64 * 8.0 / n as f64,
        n * 4
    );
    let dec = decode_levels(&payload, n, cfg);
    println!("decode roundtrip: {}", if dec == levels { "OK" } else { "MISMATCH" });
    Ok(())
}

fn describe_bins(level: i32, cfg: &CodecConfig) -> String {
    if level == 0 {
        return "sig=0".into();
    }
    let mut s = format!("sig=1 sign={}", (level < 0) as u8);
    let abs = level.unsigned_abs();
    for i in 1..=cfg.n_abs_flags.min(abs + 1) {
        if abs > i {
            s.push_str(&format!(" gr{i}=1"));
        } else {
            s.push_str(&format!(" gr{i}=0"));
            return s;
        }
    }
    s.push_str(&format!(" rem={}", abs - cfg.n_abs_flags - 1));
    s
}

/// The (S × λ) sweep subcommand: drive the parallel incremental engine
/// over the 2-D RD surface (coarse-to-fine refinement per λ-column with
/// early abandonment, or `--sweep-exhaustive` for all 257 S per column)
/// and emit the Pareto frontier + per-column argmins as
/// `BENCH_sweep.json` (+ optional CSV / container output).
fn cmd_sweep(args: &Args) -> Result<()> {
    let points = args.get_count("points", 17).map_err(|e| anyhow!(e))?;
    let workers = args.get_count("workers", 1).map_err(|e| anyhow!(e))?;
    let spec = base_spec(args)?;
    let lambdas_given = args.get("lambdas").is_some() || args.has("lambdas");
    let lambda_sweep_given = args.get("lambda-sweep").is_some() || args.has("lambda-sweep");
    if lambdas_given && lambda_sweep_given {
        bail!("--lambdas and --lambda-sweep are mutually exclusive");
    }
    let lambdas: Vec<f32> =
        if let Some(l) = args.get_f32s("lambdas").map_err(|e| anyhow!(e))? {
            l
        } else if args.has("lambdas") {
            bail!("--lambdas needs a comma-separated λ list (e.g. --lambdas 0.01,0.05,0.2)");
        } else if args.get("lambda-sweep").is_some() {
            let n = args.get_count("lambda-sweep", 5).map_err(|e| anyhow!(e))?;
            deepcabac::coordinator::sweep::default_lambda_grid(n)
        } else if args.has("lambda-sweep") {
            bail!("--lambda-sweep needs a column count (e.g. --lambda-sweep 5)");
        } else {
            vec![spec.lambda_scale]
        };
    if args.has("no-abandon") && args.has("abandon-argmin") {
        bail!("--no-abandon and --abandon-argmin are mutually exclusive");
    }
    let abandon = if args.has("no-abandon") {
        deepcabac::coordinator::AbandonMode::Off
    } else if args.has("abandon-argmin") {
        deepcabac::coordinator::AbandonMode::SelectionNeutral
    } else {
        deepcabac::coordinator::AbandonMode::FrontierPreserving
    };
    if args.has("cold") && args.has("warm-start") {
        bail!("--cold and --warm-start are mutually exclusive");
    }
    let opts = SweepOptions {
        points,
        workers,
        exhaustive: args.has("sweep-exhaustive"),
        abandon,
        warm_start: !args.has("cold"), // --warm-start is the default
        lambdas,
    };
    // validate frontier output selection BEFORE the (potentially long)
    // sweep runs: a typo'd λ or a missing --out must not cost a full
    // surface exploration
    let select_lambda: Option<f32> = match args.get("select-lambda") {
        Some(ls) => {
            let lv: f32 =
                ls.parse().map_err(|_| anyhow!("--select-lambda expects a float"))?;
            let lv = if lv == 0.0 { 0.0 } else { lv }; // -0.0 → the +0.0 column
            anyhow::ensure!(
                args.get("out").is_some(),
                "--select-lambda requires --out FILE (it selects which frontier argmin to write)"
            );
            anyhow::ensure!(
                opts.lambdas.iter().any(|l| l.to_bits() == lv.to_bits()),
                "--select-lambda {lv} is not one of the swept λ columns {:?}",
                opts.lambdas
            );
            Some(lv)
        }
        None => None,
    };
    // cheap flag-consistency checks BEFORE the sweep, like
    // --select-lambda: a usage error must not cost a surface exploration
    anyhow::ensure!(
        args.get("out-delta").is_none() || args.get("delta-from").is_some(),
        "--out-delta needs --delta-from BASE.dcbc (a plain sweep has no delta)"
    );
    // --progressive chains frontier points into one .dcbc v4 container;
    // its knobs are validated up front for the same reason
    let progressive = args.has("progressive") || args.get("progressive").is_some();
    if progressive {
        anyhow::ensure!(
            args.get("delta-from").is_none(),
            "--progressive and --delta-from are mutually exclusive \
             (tiers refine within one container; deltas diff across containers)"
        );
        anyhow::ensure!(
            select_lambda.is_none(),
            "--progressive and --select-lambda are mutually exclusive \
             (--out writes the progressive container; use materialize to extract a tier)"
        );
    } else {
        anyhow::ensure!(
            args.get("tiers").is_none() && args.get("out-tiers").is_none(),
            "--tiers / --out-tiers need --progressive"
        );
    }
    let tiers = args.get_count("tiers", 3).map_err(|e| anyhow!(e))?;
    // --eval preconditions are checked BEFORE the sweep for the same
    // reason as --select-lambda: a missing --model must not cost a full
    // surface exploration
    if args.has("eval") {
        anyhow::ensure!(
            args.get("model").is_some(),
            "--eval needs --model NAME (synthetic --arch models have no eval set)"
        );
    }
    let (name, model) = if let Some(m) = args.get("model") {
        (m.to_string(), app::load_model(m)?)
    } else if let Some(a) = args.get("arch") {
        let arch = Arch::parse(a).context("--arch must be vgg16|resnet50|mobilenet")?;
        let scale = args.get_count("scale", 8).map_err(|e| anyhow!(e))?;
        let seed = args.get_usize("seed", 42).map_err(|e| anyhow!(e))? as u64;
        (
            arch.name().to_string(),
            deepcabac::synth::generate(arch, scale, seed).to_model(),
        )
    } else {
        bail!("sweep needs --model NAME or --arch vgg16|resnet50|mobilenet");
    };

    // --delta-from flips the objective: selection minimizes the v3 delta
    // segment against this base container instead of full container
    // bytes (abandonment is forced off by the engine in this mode)
    type ProgArtifacts = (
        deepcabac::model::ProgressiveModel,
        Vec<CompressedModel>,
        Vec<deepcabac::coordinator::GridPoint>,
        Vec<deepcabac::delta::DeltaReport>,
    );
    let mut prog: Option<ProgArtifacts> = None;
    let res = if progressive {
        let ProgressiveSweep { result, progressive: chained, standalone, tier_points, reports } =
            sweep_progressive(&model, &opts, &spec, tiers)?;
        prog = Some((chained, standalone, tier_points, reports));
        result
    } else if let Some(p) = args.get("delta-from") {
        let parent = read_container(p)?;
        sweep_delta(&parent, &model, &opts, &spec)?
    } else {
        sweep_s_auto(&model, &opts, &spec)?
    };
    let best = res.best_point;
    println!(
        "{name}: best (S={}, λ={}) -> {} ({:.2}% of original, x{:.1}); \
         {} probes / {} λ-columns in {} rounds, {} abandoned ({} mode), \
         frontier {} points, {:.2}s ({} workers)",
        best.s,
        best.lambda_scale,
        human_bytes(res.best.1.compressed_bytes),
        res.best.1.ratio_percent(),
        res.best.1.factor(),
        res.stats.probes_total,
        res.stats.columns,
        res.stats.rounds,
        res.stats.probes_abandoned,
        opts.abandon.name(),
        res.frontier.len(),
        res.stats.wall_s,
        workers,
    );
    if let Some((dm, dr)) = &res.best_delta {
        println!(
            "delta objective: winner's delta segment {} against parent {:016x} \
             ({}/{} layers coded, residual density {:.3}%)",
            human_bytes(dm.total_bytes()),
            dm.parent_fp,
            dm.coded_layers(),
            dm.layers.len(),
            dr.residual_density() * 100.0,
        );
        if let Some(out) = args.get("out-delta") {
            std::fs::write(out, dm.serialize())?;
            println!("wrote {out}");
        }
    }
    if opts.warm_start && res.stats.seeded_weights > 0 {
        println!(
            "warm start: {} of {} seeded weight scans hit ({:.1}%)",
            res.stats.seed_hits,
            res.stats.seeded_weights,
            res.stats.seed_hit_rate() * 100.0,
        );
    }
    for c in &res.columns {
        println!(
            "  λ={:<8} best S={:>3} -> {} ({} probes, {} abandoned)",
            c.lambda_scale,
            c.s,
            human_bytes(c.bytes),
            c.probes,
            c.abandoned,
        );
    }

    if let Some((chained, standalone, tier_points, reports)) = &prog {
        let body_lens = chained.tier_body_lens();
        let total = chained.total_bytes();
        let finest = standalone.last().map(|c| c.serialize().len()).unwrap_or(0);
        println!(
            "progressive: {} tiers chained into {} ({:.1}% of the finest \
             standalone container's {})",
            chained.n_tiers(),
            human_bytes(total),
            total as f64 / finest.max(1) as f64 * 100.0,
            human_bytes(finest),
        );
        for (t, c) in standalone.iter().enumerate() {
            let pt = tier_points[t];
            let refinement = if t == 0 {
                String::new()
            } else {
                format!(
                    ", residual density {:.3}%",
                    reports[t - 1].residual_density() * 100.0
                )
            };
            println!(
                "  tier {t}: S={:>3} λ={:<8} body {} (standalone {}{refinement})",
                pt.s,
                pt.lambda_scale,
                human_bytes(body_lens[t]),
                human_bytes(c.serialize().len()),
            );
        }
        if let Some(dir) = args.get("out-tiers") {
            let dir = std::path::PathBuf::from(dir);
            std::fs::create_dir_all(&dir)?;
            for (t, c) in standalone.iter().enumerate() {
                let p = dir.join(format!("tier_{t}.dcbc"));
                std::fs::write(&p, c.serialize())?;
                println!("wrote {p:?}");
            }
        }
        let tiers_json: Vec<Json> = standalone
            .iter()
            .enumerate()
            .map(|(t, c)| {
                let pt = tier_points[t];
                let mut fields = vec![
                    ("tier", json::num(t as f64)),
                    ("s", json::num(pt.s as f64)),
                    ("lambda_scale", json::num(pt.lambda_scale as f64)),
                    ("standalone_bytes", json::num(c.serialize().len() as f64)),
                    ("tier_body_bytes", json::num(body_lens[t] as f64)),
                ];
                if let Some(p) = res.points.iter().find(|p| {
                    !p.abandoned
                        && p.s == pt.s
                        && p.lambda_scale.to_bits() == pt.lambda_scale.to_bits()
                }) {
                    fields.push(("distortion", json::num(p.distortion)));
                }
                if t > 0 {
                    fields.push((
                        "residual_density",
                        json::num(reports[t - 1].residual_density()),
                    ));
                }
                json::obj(fields)
            })
            .collect();
        let j = json::obj(vec![
            ("bench", json::s("progressive")),
            ("model", json::s(&name)),
            ("n_tiers", json::num(chained.n_tiers() as f64)),
            ("requested_tiers", json::num(tiers as f64)),
            ("progressive_bytes", json::num(total as f64)),
            ("finest_standalone_bytes", json::num(finest as f64)),
            ("overhead_ratio", json::num(total as f64 / finest.max(1) as f64)),
            ("workers", json::num(workers as f64)),
            ("tiers", json::arr(tiers_json)),
        ]);
        std::fs::write("BENCH_progressive.json", j.to_string_pretty())?;
        println!("wrote BENCH_progressive.json");
    }

    // serial single-point reference: recompress every completed grid
    // point through the plain serial pipeline and verify byte-identity
    // against the engine's per-point fingerprints (the acceptance
    // contract: every cell of the surface is exactly what a one-shot
    // `compress` at that (S, λ) would have produced)
    let wall_serial = if args.has("compare-serial") {
        let t = Timer::new();
        let mut checked = 0usize;
        for p in res.points.iter().filter(|p| !p.abandoned) {
            let pspec =
                CompressionSpec { s: p.s, lambda_scale: p.lambda_scale, ..spec };
            let (c, _) = compress_model(&model, &pspec, 1);
            let ser = c.serialize();
            anyhow::ensure!(
                ser.len() == p.compressed_bytes && fnv1a(&ser) == p.container_hash,
                "grid point (S={}, λ={}) diverges from the serial \
                 single-point pipeline (engine determinism violated)",
                p.s,
                p.lambda_scale
            );
            checked += 1;
        }
        let best_spec =
            CompressionSpec { s: best.s, lambda_scale: best.lambda_scale, ..spec };
        let (c, _) = compress_model(&model, &best_spec, 1);
        anyhow::ensure!(
            c.serialize() == res.best.0.serialize(),
            "best container diverges from its serial recompress"
        );
        let wall = t.elapsed_s();
        println!(
            "serial reference: {checked} completed grid points byte-identical \
             ({wall:.2}s serial vs {:.2}s engine)",
            res.stats.wall_s,
        );
        Some(wall)
    } else {
        None
    };

    // write every artifact BEFORE --eval runs: a PJRT failure (the
    // vendored xla stub errors at runtime by design) must not discard a
    // completed surface exploration
    let json_path = args.get_or("json", "BENCH_sweep.json");
    let no_metrics: Vec<Option<f64>> = vec![None; res.columns.len()];
    std::fs::write(
        json_path,
        sweep_to_json(&name, &opts, &res, wall_serial, &no_metrics).to_string_pretty(),
    )?;
    println!("wrote {json_path}");

    if let Some(csv_path) = args.get("csv") {
        let rows: Vec<Vec<String>> = res
            .points
            .iter()
            .map(|p| {
                vec![
                    p.s.to_string(),
                    format!("{}", p.lambda_scale),
                    p.compressed_bytes.to_string(),
                    format!("{:.6}", p.density),
                    format!("{:.6e}", p.distortion),
                    (p.abandoned as u8).to_string(),
                    p.seeded.to_string(),
                    p.seed_hits.to_string(),
                    format!("{:.3}", p.wall_s * 1e3),
                ]
            })
            .collect();
        let csv = deepcabac::report::to_csv(
            &[
                "S", "lambda_scale", "bytes", "density", "distortion", "abandoned",
                "seeded", "seed_hits", "wall_ms",
            ],
            &rows,
        );
        std::fs::write(csv_path, &csv)?;
        println!("wrote {csv_path}");
    }

    if let Some(out) = args.get("out") {
        if let Some((chained, ..)) = &prog {
            // --progressive: --out writes the chained v4 container
            std::fs::write(out, chained.serialize())?;
            println!("wrote {out} (progressive v4, {} tiers)", chained.n_tiers());
        } else {
            // frontier output selection: default = the overall smallest
            // container; --select-lambda X = λ-column X's argmin instead
            // (validated against the λ grid before the sweep ran)
            let container = if let Some(lv) = select_lambda {
                let col = res
                    .columns
                    .iter()
                    .find(|c| c.lambda_scale.to_bits() == lv.to_bits())
                    .ok_or_else(|| {
                        anyhow!("λ column {lv} vanished from the sweep result (engine bug)")
                    })?;
                println!(
                    "selected λ={} column argmin (S={}, {})",
                    col.lambda_scale,
                    col.s,
                    human_bytes(col.bytes),
                );
                &col.model
            } else {
                &res.best.0
            };
            std::fs::write(out, container.serialize())?;
            println!("wrote {out}");
        }
    }

    // --eval restores the accuracy dimension the deleted serial
    // examples/rd_sweep.rs used to print: decompress each λ-column's
    // argmin and re-evaluate it through PJRT, then rewrite the JSON with
    // the per-column metric. Runs LAST so an eval failure leaves every
    // sweep artifact already on disk.
    if args.has("eval") {
        let rt = Runtime::cpu()?;
        let mut col_metrics = Vec::with_capacity(res.columns.len());
        for c in &res.columns {
            let m = app::evaluate_compressed(&rt, &model, &c.model)?.metric;
            println!("  λ={:<8} metric after decompress: {m:.4}", c.lambda_scale);
            col_metrics.push(Some(m));
        }
        std::fs::write(
            json_path,
            sweep_to_json(&name, &opts, &res, wall_serial, &col_metrics)
                .to_string_pretty(),
        )?;
        println!("rewrote {json_path} with per-column metrics");
    }
    Ok(())
}

fn sweep_to_json(
    name: &str,
    opts: &SweepOptions,
    res: &SweepResult,
    wall_serial: Option<f64>,
    col_metrics: &[Option<f64>],
) -> Json {
    let best = res.best_point;
    let points: Vec<Json> = res
        .points
        .iter()
        .map(|p| {
            json::obj(vec![
                ("s", json::num(p.s as f64)),
                ("lambda_scale", json::num(p.lambda_scale as f64)),
                ("bytes", json::num(p.compressed_bytes as f64)),
                ("density", json::num(p.density)),
                ("distortion", json::num(p.distortion)),
                ("abandoned", Json::Bool(p.abandoned)),
                (
                    "abandon_reason",
                    p.abandon_kind.map(|k| json::s(k.name())).unwrap_or(Json::Null),
                ),
                (
                    "delta_bytes",
                    p.delta_bytes.map(|b| json::num(b as f64)).unwrap_or(Json::Null),
                ),
                ("seeded", json::num(p.seeded as f64)),
                ("seed_hits", json::num(p.seed_hits as f64)),
                ("wall_ms", json::num(p.wall_s * 1e3)),
            ])
        })
        .collect();
    let frontier: Vec<Json> = res
        .frontier
        .iter()
        .map(|&i| {
            let p = &res.points[i];
            json::obj(vec![
                ("s", json::num(p.s as f64)),
                ("lambda_scale", json::num(p.lambda_scale as f64)),
                ("bytes", json::num(p.compressed_bytes as f64)),
                ("distortion", json::num(p.distortion)),
            ])
        })
        .collect();
    let columns: Vec<Json> = res
        .columns
        .iter()
        .enumerate()
        .map(|(i, c)| {
            let mut fields = vec![
                ("lambda_scale", json::num(c.lambda_scale as f64)),
                ("best_s", json::num(c.s as f64)),
                ("best_bytes", json::num(c.bytes as f64)),
                ("probes", json::num(c.probes as f64)),
                ("abandoned", json::num(c.abandoned as f64)),
            ];
            if let Some(m) = col_metrics.get(i).copied().flatten() {
                fields.push(("metric", json::num(m)));
            }
            json::obj(fields)
        })
        .collect();
    let mut fields = vec![
        ("bench", json::s("sweep")),
        ("model", json::s(name)),
        ("workers", json::num(opts.workers as f64)),
        ("points_per_round", json::num(opts.points as f64)),
        ("exhaustive", Json::Bool(opts.exhaustive)),
        ("abandon_mode", json::s(opts.abandon.name())),
        ("warm_start", Json::Bool(opts.warm_start)),
        ("lambdas", json::arr(res.columns.iter().map(|c| json::num(c.lambda_scale as f64)).collect())),
        ("lambda_columns", json::num(res.stats.columns as f64)),
        ("rounds", json::num(res.stats.rounds as f64)),
        ("probes_total", json::num(res.stats.probes_total as f64)),
        ("probes_abandoned", json::num(res.stats.probes_abandoned as f64)),
        ("abandoned_mid_layer", json::num(res.stats.abandoned_mid_layer as f64)),
        ("abandoned_boundary", json::num(res.stats.abandoned_boundary as f64)),
        ("seeded_weights", json::num(res.stats.seeded_weights as f64)),
        ("seed_hits", json::num(res.stats.seed_hits as f64)),
        ("seed_hit_rate", json::num(res.stats.seed_hit_rate())),
        ("best_s", json::num(best.s as f64)),
        ("best_lambda", json::num(best.lambda_scale as f64)),
        ("best_bytes", json::num(res.best.1.compressed_bytes as f64)),
        ("raw_bytes", json::num(res.best.1.raw_bytes as f64)),
        ("wall_s", json::num(res.stats.wall_s)),
        ("points", json::arr(points)),
        ("frontier", json::arr(frontier)),
        ("columns", json::arr(columns)),
    ];
    if let Some(w) = wall_serial {
        fields.push(("wall_s_serial", json::num(w)));
    }
    if let Some((dm, dr)) = &res.best_delta {
        fields.push((
            "delta",
            json::obj(vec![
                ("parent_fingerprint", json::s(&format!("{:016x}", dm.parent_fp))),
                ("delta_bytes", json::num(dm.total_bytes() as f64)),
                ("delta_payload_bytes", json::num(dm.payload_bytes() as f64)),
                ("coded_layers", json::num(dm.coded_layers() as f64)),
                ("total_layers", json::num(dm.layers.len() as f64)),
                ("residual_density", json::num(dr.residual_density())),
            ]),
        ));
    }
    json::obj(fields)
}

fn read_container(path: &str) -> Result<CompressedModel> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {path}"))?;
    CompressedModel::deserialize(&bytes)
        .with_context(|| format!("{path} is not a full .dcbc container (v1/v2)"))
}

/// `deepcabac materialize`: extract one tier of a progressive (v4)
/// container as a standalone v1/v2 container — byte-identical to the
/// container that tier was chained from (the CI smoke job `cmp`s this
/// against the sweep's `--out-tiers` output).
fn cmd_materialize(args: &Args) -> Result<()> {
    let input = args.get("in").context("--in required")?;
    let out = args.get("out").context("--out required")?;
    let workers = args.get_count("workers", 1).map_err(|e| anyhow!(e))?;
    let bytes = std::fs::read(input).with_context(|| format!("reading {input}"))?;
    let prog = match deserialize_any(&bytes)? {
        Container::Progressive(p) => p,
        Container::Full(_) => bail!(
            "{input} is already a standalone container (v1/v2) — nothing to materialize"
        ),
        Container::Delta(_) => {
            bail!("{input} is a v3 delta segment — use `delta apply`, not materialize")
        }
    };
    let tier = match args.get("tier") {
        None => prog.n_tiers() - 1,
        Some(v) => v.parse().map_err(|_| anyhow!("--tier expects a tier index"))?,
    };
    let c = deepcabac::delta::materialize(&prog, tier, workers)?;
    let ser = c.serialize();
    std::fs::write(out, &ser)?;
    println!(
        "{}: tier {tier} of {} materialized -> {out} ({})",
        c.name,
        prog.n_tiers(),
        human_bytes(ser.len()),
    );
    Ok(())
}

/// `deepcabac delta encode`: diff two full containers into a v3 delta
/// segment (`apply` turns it back into the target byte-for-byte).
fn cmd_delta_encode(args: &Args) -> Result<()> {
    let parent = read_container(args.get("parent").context("--parent required")?)?;
    let target = read_container(args.get("target").context("--target required")?)?;
    let out = args.get("out").context("--out required")?;
    let workers = args.get_count("workers", 1).map_err(|e| anyhow!(e))?;
    let (delta, report) = deepcabac::delta::encode(&parent, &target, workers)?;
    let ser = delta.serialize();
    std::fs::write(out, &ser)?;
    let full = target.serialize().len();
    println!(
        "{}: delta {} vs full {} ({:.2}% of full), {}/{} layers coded, \
         residual density {:.3}%, parent {:016x}",
        delta.name,
        human_bytes(ser.len()),
        human_bytes(full),
        ser.len() as f64 / full.max(1) as f64 * 100.0,
        delta.coded_layers(),
        delta.layers.len(),
        report.residual_density() * 100.0,
        delta.parent_fp,
    );
    println!("wrote {out}");
    Ok(())
}

/// `deepcabac delta apply`: reconstruct the target container from a base
/// container plus a delta segment.
fn cmd_delta_apply(args: &Args) -> Result<()> {
    let parent = read_container(args.get("parent").context("--parent required")?)?;
    let delta_path = args.get("delta").context("--delta required")?;
    let out = args.get("out").context("--out required")?;
    let workers = args.get_count("workers", 1).map_err(|e| anyhow!(e))?;
    let delta = DeltaModel::deserialize(&std::fs::read(delta_path)?)
        .with_context(|| format!("{delta_path} is not a .dcbc v3 delta segment"))?;
    let applied = deepcabac::delta::apply(&parent, &delta, workers)?;
    let ser = applied.serialize();
    std::fs::write(out, &ser)?;
    println!(
        "{}: applied {} delta onto base {:016x} -> {} ({} layers, {} skipped)",
        applied.name,
        human_bytes(delta.total_bytes()),
        delta.parent_fp,
        human_bytes(ser.len()),
        applied.layers.len(),
        applied.layers.len() - delta.coded_layers(),
    );
    println!("wrote {out}");
    Ok(())
}

/// `deepcabac delta bench`: size + latency accounting for the
/// incremental-delivery story. Encodes the delta, verifies the apply
/// round trip is byte-identical to the target, then times `--iters`
/// apply runs and writes `BENCH_delta.json`.
fn cmd_delta_bench(args: &Args) -> Result<()> {
    let parent_path = args.get("parent").context("--parent required")?;
    let target_path = args.get("target").context("--target required")?;
    let parent = read_container(parent_path)?;
    let target = read_container(target_path)?;
    let workers = args.get_count("workers", 1).map_err(|e| anyhow!(e))?;
    let iters = args.get_count("iters", 32).map_err(|e| anyhow!(e))?;

    let t = Timer::new();
    let (delta, report) = deepcabac::delta::encode(&parent, &target, workers)?;
    let encode_s = t.elapsed_s();
    let full_bytes = target.serialize();
    let delta_bytes = delta.total_bytes();

    // the acceptance contract before any timing: decode–apply must
    // reproduce the target container exactly
    let applied = deepcabac::delta::apply(&parent, &delta, workers)?;
    anyhow::ensure!(
        applied.serialize() == full_bytes,
        "delta apply diverged from the target container (round-trip broken)"
    );

    let mut lat_ms: Vec<f64> = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Timer::new();
        let a = deepcabac::delta::apply(&parent, &delta, workers)?;
        lat_ms.push(t.elapsed_s() * 1e3);
        // keep the optimizer honest without re-serializing every iter
        anyhow::ensure!(a.layers.len() == target.layers.len());
    }
    lat_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |p: f64| lat_ms[((lat_ms.len() as f64 * p) as usize).min(lat_ms.len() - 1)];
    let (p50, p99) = (pct(0.50), pct(0.99));

    let ratio = delta_bytes as f64 / full_bytes.len().max(1) as f64;
    println!(
        "{}: delta {} vs full {} ({:.2}% of full, {}/{} layers coded, \
         residual density {:.3}%)",
        delta.name,
        human_bytes(delta_bytes),
        human_bytes(full_bytes.len()),
        ratio * 100.0,
        delta.coded_layers(),
        delta.layers.len(),
        report.residual_density() * 100.0,
    );
    println!(
        "apply: p50 {p50:.2} ms, p99 {p99:.2} ms over {iters} iters ({workers} workers); \
         encode {encode_s:.2}s"
    );

    let json_path = args.get_or("json", "BENCH_delta.json");
    let layers: Vec<Json> = report
        .layers
        .iter()
        .map(|l| {
            json::obj(vec![
                ("name", json::s(&l.name)),
                ("skipped", Json::Bool(l.skipped)),
                ("n_weights", json::num(l.n_weights as f64)),
                ("residual_nonzero", json::num(l.residual_nonzero as f64)),
                ("delta_payload", json::num(l.delta_payload as f64)),
                ("target_payload", json::num(l.target_payload as f64)),
            ])
        })
        .collect();
    let j = json::obj(vec![
        ("bench", json::s("delta")),
        ("model", json::s(&delta.name)),
        ("parent", json::s(parent_path)),
        ("target", json::s(target_path)),
        ("parent_fingerprint", json::s(&format!("{:016x}", delta.parent_fp))),
        ("full_bytes", json::num(full_bytes.len() as f64)),
        ("delta_bytes", json::num(delta_bytes as f64)),
        ("delta_payload_bytes", json::num(delta.payload_bytes() as f64)),
        ("delta_ratio", json::num(ratio)),
        ("coded_layers", json::num(delta.coded_layers() as f64)),
        ("total_layers", json::num(delta.layers.len() as f64)),
        ("residual_density", json::num(report.residual_density())),
        ("encode_wall_s", json::num(encode_s)),
        ("apply_iters", json::num(iters as f64)),
        ("apply_p50_ms", json::num(p50)),
        ("apply_p99_ms", json::num(p99)),
        ("workers", json::num(workers as f64)),
        ("layers", json::arr(layers)),
    ]);
    std::fs::write(json_path, j.to_string_pretty())?;
    println!("wrote {json_path}");
    Ok(())
}

fn cmd_synth(args: &Args) -> Result<()> {
    let arch = Arch::parse(args.get_or("arch", "vgg16"))
        .context("--arch must be vgg16|resnet50|mobilenet")?;
    let scale = args.get_usize("scale", 8).map_err(|e| anyhow!(e))?;
    let spec = CompressionSpec {
        s: args.get_usize("s", 64).map_err(|e| anyhow!(e))? as u32,
        ..base_spec(args)?
    };
    // --perturb-density: the delta-fixture path. Regenerate the same
    // base model (same --seed), nudge a deterministic sparse subset of
    // weights, and compress that — two runs differing only in
    // --perturb-density produce a (parent, target) container pair for
    // `deepcabac delta` (density 0 = the unperturbed base through the
    // identical compression path).
    if args.get("perturb-density").is_some() {
        let density = args.get_f32("perturb-density", 0.0).map_err(|e| anyhow!(e))?;
        anyhow::ensure!(
            density.is_finite() && (0.0..=1.0).contains(&density),
            "--perturb-density must be in [0, 1]"
        );
        let pscale = args.get_f32("perturb-scale", 0.05).map_err(|e| anyhow!(e))?;
        anyhow::ensure!(
            pscale.is_finite() && pscale > 0.0,
            "--perturb-scale must be a positive float"
        );
        let seed = args.get_usize("seed", 42).map_err(|e| anyhow!(e))? as u64;
        let pseed = args.get_usize("perturb-seed", 1).map_err(|e| anyhow!(e))? as u64;
        let workers = args.get_count("workers", 1).map_err(|e| anyhow!(e))?;
        let mut model = deepcabac::synth::generate(arch, scale, seed).to_model();
        let mut rng = deepcabac::util::SplitMix64::new(pseed);
        let mut touched = 0usize;
        for t in &mut model.weights {
            if t.data.is_empty() {
                continue;
            }
            let n = (t.data.len() as f64 * density as f64).round() as usize;
            for _ in 0..n {
                let i = rng.below(t.data.len() as u64) as usize;
                t.data[i] += pscale * rng.normal() as f32;
                touched += 1;
            }
        }
        let (compressed, report) = compress_model(&model, &spec, workers);
        println!(
            "{} (1/{scale} scale, {touched} weights perturbed at density {density}): \
             {} raw, compressed {} ({:.2}%, x{:.1})",
            arch.name(),
            human_bytes(report.raw_bytes),
            human_bytes(report.compressed_bytes),
            report.ratio_percent(),
            report.factor(),
        );
        if let Some(out) = args.get("out") {
            std::fs::write(out, compressed.serialize())?;
            println!("wrote {out}");
        }
        return Ok(());
    }
    let row = app::table1_large_row(arch, scale, &[spec.s], &spec, 1, 42)?;
    println!(
        "{} (1/{scale} scale): {} raw, density {:.2}%, compressed {} ({:.2}%, x{:.1})",
        arch.name(),
        human_bytes(row.org_bytes),
        row.sparsity_pct,
        human_bytes(row.report.compressed_bytes),
        row.ratio_pct,
        row.report.factor(),
    );
    if let Some(out) = args.get("out") {
        std::fs::write(out, row.compressed.serialize())?;
        println!("wrote {out}");
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    use deepcabac::serve::Backend;
    let opts = deepcabac::serve::ServeOptions {
        dir: std::path::PathBuf::from(args.get("dir").context("--dir required")?),
        addr: args.get_or("addr", "127.0.0.1:8080").to_string(),
        cache_bytes: args.get_usize("cache-mb", 64).map_err(|e| anyhow!(e))? << 20,
        workers: args
            .get_count(
                "workers",
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
            )
            .map_err(|e| anyhow!(e))?,
        // get_count rejects 0: a zero deadline would time out every read
        read_timeout: std::time::Duration::from_millis(
            args.get_count("read-timeout", 10_000).map_err(|e| anyhow!(e))? as u64,
        ),
        write_timeout: std::time::Duration::from_millis(
            args.get_count("write-timeout", 30_000).map_err(|e| anyhow!(e))? as u64,
        ),
        max_connections: match args.get("max-connections") {
            Some(_) => args.get_count("max-connections", 0).map_err(|e| anyhow!(e))?,
            None => usize::MAX,
        },
    };
    let backend = match (args.has("event-loop"), args.has("threaded")) {
        (true, true) => bail!("--event-loop and --threaded are mutually exclusive"),
        (true, false) => Backend::Event,
        (false, true) => Backend::Threaded,
        // default: the scalable readiness loop wherever the platform
        // supports it, thread-per-connection elsewhere
        (false, false) => {
            if deepcabac::util::poll::supported() {
                Backend::Event
            } else {
                Backend::Threaded
            }
        }
    };
    let handle = deepcabac::serve::server::start_with(backend, opts.clone())?;
    // the smoke script greps this exact line for the ephemeral port
    println!("listening on http://{}", handle.addr());
    println!(
        "serving {:?} ({} backend, {} workers, {} cache{})",
        opts.dir,
        match backend {
            Backend::Event => "event-loop",
            Backend::Threaded => "threaded",
        },
        opts.workers,
        human_bytes(opts.cache_bytes),
        if opts.max_connections == usize::MAX {
            String::new()
        } else {
            format!(", max {} connections", opts.max_connections)
        },
    );
    use std::io::Write as _;
    std::io::stdout().flush().ok();
    // foreground server: block until killed
    loop {
        std::thread::park();
    }
}

/// Layer names from a remote container (or response header) are
/// attacker-controlled: reduce them to a single safe path component so
/// `--out-dir` writes can never traverse outside the output directory.
fn safe_file_stem(name: &str) -> String {
    let cleaned: String = name
        .chars()
        .map(|c| {
            if c.is_alphanumeric() || matches!(c, '-' | '_' | '.') { c } else { '_' }
        })
        .collect();
    let cleaned = cleaned.trim_matches('.').to_string();
    if cleaned.is_empty() {
        "layer".to_string()
    } else {
        cleaned
    }
}

fn cmd_fetch(args: &Args) -> Result<()> {
    use deepcabac::serve::http;
    use deepcabac::serve::{StreamDecoder, StreamEvent};

    let url = args.get("url").context("--url required (http://HOST:PORT/models/NAME)")?;
    let (addr, path) = http::parse_url(url)?;
    let path = path.trim_end_matches('/').to_string();
    let out_dir = args.get("out-dir").map(std::path::PathBuf::from);
    if let Some(d) = &out_dir {
        std::fs::create_dir_all(d)?;
    }
    let exclusive = [
        args.get("layer").is_some(),
        args.get("from").is_some(),
        args.get("tier").is_some(),
        args.get("upgrade").is_some(),
    ];
    anyhow::ensure!(
        exclusive.iter().filter(|&&b| b).count() <= 1,
        "--layer, --from, --tier and --upgrade are mutually exclusive"
    );

    if let Some(ts) = args.get("tier") {
        // progressive prefix fetch: ask the server for the container cut
        // at a tier boundary and decode it tier by tier as bytes arrive
        let t: usize = ts.parse().map_err(|_| anyhow!("--tier expects a tier index"))?;
        let workers = args.get_count("workers", 1).map_err(|e| anyhow!(e))?;
        let mut applier = deepcabac::delta::ProgressiveApplier::new(workers);
        let mut raw: Vec<u8> = Vec::new();
        let mut last: Option<deepcabac::delta::TierSnapshot> = None;
        let tier_path = format!("{path}?tier={t}");
        let (status, _headers, err_body) =
            http::get_streaming(&addr, &tier_path, None, &mut |chunk| {
                raw.extend_from_slice(chunk);
                for snap in applier.feed(chunk)? {
                    eprintln!(
                        "[fetch] tier {}/{} usable after {} bytes ({} layers)",
                        snap.tier,
                        snap.n_tiers,
                        raw.len(),
                        snap.layers.len(),
                    );
                    last = Some(snap);
                }
                Ok(())
            })?;
        anyhow::ensure!(
            status == 200,
            "HTTP {status} fetching {tier_path}: {}",
            String::from_utf8_lossy(&err_body).trim()
        );
        let complete = applier.finish()?;
        anyhow::ensure!(
            complete == t + 1,
            "server sent {complete} complete tiers, expected {}",
            t + 1
        );
        let snap = last.context("stream ended before any tier completed")?;
        println!(
            "{url} tier {t}: {} layers usable from a {}-byte prefix ({}/{} tiers held)",
            snap.layers.len(),
            raw.len(),
            complete,
            snap.n_tiers,
        );
        if let Some(o) = args.get("out") {
            std::fs::write(o, &raw)?;
            println!("wrote {o} (progressive prefix — extend it later with --upgrade {o})");
        }
        if let Some(d) = &out_dir {
            for l in &snap.layers {
                let p = d.join(format!("{}.w.npy", safe_file_stem(&l.name)));
                npy::write_npy_f32(&p, &l.dims, &l.weights)?;
                println!("wrote {p:?}");
            }
        }
        return Ok(());
    }

    if let Some(local_path) = args.get("upgrade") {
        // tier upgrade: extend a locally held progressive prefix to the
        // server's full container with one Range request for the tail
        let workers = args.get_count("workers", 1).map_err(|e| anyhow!(e))?;
        let mut bytes =
            std::fs::read(local_path).with_context(|| format!("reading {local_path}"))?;
        let local = match deserialize_any(&bytes)? {
            Container::Progressive(p) => p,
            _ => bail!(
                "{local_path} is not a progressive (v4) container — \
                 only --tier prefixes can be upgraded"
            ),
        };
        let have = local.n_tiers();
        // open-ended tail request; the server clamps the end to its
        // container length (RFC 7233), 416 = nothing past our prefix
        let resp = http::get(&addr, &path, Some((bytes.len() as u64, u64::MAX >> 1)))?;
        if resp.status == 416 {
            println!(
                "{local_path}: already complete at {} tiers ({} bytes) — nothing to fetch",
                have,
                bytes.len(),
            );
            return Ok(());
        }
        anyhow::ensure!(
            resp.status == 206,
            "HTTP {} fetching the container tail from {url}: {}",
            resp.status,
            String::from_utf8_lossy(&resp.body).trim()
        );
        let tail = resp.body.len();
        bytes.extend_from_slice(&resp.body);
        // deep-validate the spliced container: the tail must decode as
        // refinement tiers of the exact prefix we hold
        let mut applier = deepcabac::delta::ProgressiveApplier::new(workers);
        let mut snaps = applier.feed(&bytes).with_context(|| {
            format!(
                "{local_path} + fetched tail do not form a valid progressive container \
                 (was the model replaced on the server? re-fetch it in full)"
            )
        })?;
        let complete = applier.finish()?;
        let snap = snaps.pop().context("upgraded container has no tiers")?;
        println!(
            "{local_path}: upgraded {have} -> {complete} tiers (+{tail} bytes tail, \
             {} layers at the finest tier)",
            snap.layers.len(),
        );
        let out = args.get_or("out", local_path);
        std::fs::write(out, &bytes)?;
        println!("wrote {out}");
        if let Some(d) = &out_dir {
            for l in &snap.layers {
                let p = d.join(format!("{}.w.npy", safe_file_stem(&l.name)));
                npy::write_npy_f32(&p, &l.dims, &l.weights)?;
                println!("wrote {p:?}");
            }
        }
        return Ok(());
    }

    if let Some(layer) = args.get("layer") {
        // random access: one layer's server-side-decoded weights
        let resp = http::get(&addr, &format!("{path}/layers/{layer}/weights"), None)?;
        anyhow::ensure!(resp.status == 200, "HTTP {} fetching layer {layer}", resp.status);
        let dims: Vec<usize> = resp
            .header("x-dims")
            .unwrap_or("")
            .split(',')
            .filter_map(|d| d.parse().ok())
            .collect();
        let name = resp.header("x-layer-name").unwrap_or(layer).to_string();
        anyhow::ensure!(resp.body.len() % 4 == 0, "weight body not f32-aligned");
        let mut weights = vec![0f32; resp.body.len() / 4];
        byteorder::LittleEndian::read_f32_into(&resp.body, &mut weights);
        println!(
            "{name}: {} weights, dims {dims:?}, {} (cache {})",
            weights.len(),
            human_bytes(resp.body.len()),
            resp.header("x-cache").unwrap_or("?"),
        );
        if let Some(d) = &out_dir {
            let shape = if dims.is_empty() { vec![weights.len()] } else { dims };
            let p = d.join(format!("{}.w.npy", safe_file_stem(&name)));
            npy::write_npy_f32(&p, &shape, &weights)?;
            println!("wrote {p:?}");
        }
        return Ok(());
    }

    if let Some(base_path) = args.get("from") {
        // incremental update: ask the server for a delta against the
        // local base container and apply it in place as bytes arrive
        let workers = args.get_count("workers", 1).map_err(|e| anyhow!(e))?;
        let parent = read_container(base_path)?;
        let fp = fingerprint(&parent);
        let mut applier = deepcabac::delta::StreamApplier::new(&parent, workers);
        let mut layers = Vec::new();
        let delta_path = format!("{path}/delta?from={fp:016x}");
        let (status, _headers, err_body) =
            http::get_streaming(&addr, &delta_path, None, &mut |chunk| {
                for l in applier.feed(chunk)? {
                    if l.skipped {
                        eprintln!(
                            "[fetch] layer {} ({}): unchanged — reconstructed from {base_path}",
                            l.index, l.name
                        );
                    } else {
                        eprintln!(
                            "[fetch] layer {} ({}): {} weights patched mid-stream",
                            l.index, l.name, l.n_weights
                        );
                    }
                    layers.push(l);
                }
                Ok(())
            })?;
        if status == 409 {
            bail!(
                "server knows base {fp:016x} but has no delta from it (HTTP 409) — \
                 fetch the full container instead: {}",
                String::from_utf8_lossy(&err_body).trim()
            );
        }
        anyhow::ensure!(
            status == 200,
            "HTTP {status} fetching {delta_path}: {}",
            String::from_utf8_lossy(&err_body).trim()
        );
        applier.finish()?;
        println!(
            "{}: {} layers reconstructed from base {base_path} + streamed delta",
            url,
            layers.len(),
        );
        if let Some(d) = &out_dir {
            for l in &layers {
                let p = d.join(format!("{}.w.npy", safe_file_stem(&l.name)));
                npy::write_npy_f32(&p, &l.dims, &l.weights)?;
                println!("wrote {p:?}");
            }
        }
        return Ok(());
    }

    // whole container: drive the streaming decoder straight off the socket
    let mut dec = StreamDecoder::new();
    let mut layers = Vec::new();
    let (status, _headers, err_body) = http::get_streaming(&addr, &path, None, &mut |chunk| {
        for ev in dec.feed(chunk)? {
            match ev {
                StreamEvent::Start { model, version, n_layers, parent_fp } => {
                    match parent_fp {
                        Some(fp) => eprintln!(
                            "[fetch] {model} v{version}: {n_layers} layers incoming \
                             (delta segment, parent {fp:016x} — use --from to apply it)"
                        ),
                        None => eprintln!(
                            "[fetch] {model} v{version}: {n_layers} layers incoming"
                        ),
                    }
                }
                StreamEvent::Chunk { layer, chunk, n_chunks, .. } => {
                    if n_chunks > 1 {
                        eprintln!("[fetch]   layer {layer}: chunk {}/{n_chunks}", chunk + 1);
                    }
                }
                StreamEvent::Layer(l) => {
                    eprintln!(
                        "[fetch] layer {} ({}): {} weights decoded mid-stream",
                        l.index,
                        l.name,
                        l.n_weights
                    );
                    layers.push(*l);
                }
                StreamEvent::Tier { tier, n_tiers } => {
                    eprintln!(
                        "[fetch] tier {}/{n_tiers} complete — the bytes so far are a \
                         usable container (use --tier to reconstruct per-tier weights)",
                        tier + 1,
                    );
                }
                StreamEvent::End => {}
            }
        }
        Ok(())
    })?;
    anyhow::ensure!(
        status == 200,
        "HTTP {status} fetching {url}: {}",
        String::from_utf8_lossy(&err_body)
    );
    dec.finish()?;
    println!(
        "{}: {} layers, {} container bytes streamed",
        url,
        layers.len(),
        dec.bytes_consumed(),
    );
    if let Some(d) = &out_dir {
        for l in &layers {
            let p = d.join(format!("{}.w.npy", safe_file_stem(&l.name)));
            npy::write_npy_f32(&p, &l.dims, &l.weights)?;
            println!("wrote {p:?}");
        }
    }
    Ok(())
}

fn cmd_loadgen(args: &Args) -> Result<()> {
    let rate = match args.get("rate") {
        Some(v) => {
            let r: f64 =
                v.parse().map_err(|_| anyhow!("--rate must be a number, got {v:?}"))?;
            anyhow::ensure!(r > 0.0, "--rate must be positive, got {r}");
            Some(r)
        }
        None => None,
    };
    let sweep = match args.get("connections-sweep") {
        Some(list) => Some(parse_connection_counts(list)?),
        None => None,
    };
    let opts = deepcabac::serve::loadgen::LoadgenOptions {
        url: args.get("url").context("--url required (http://HOST:PORT)")?.to_string(),
        clients: args.get_count("clients", 8).map_err(|e| anyhow!(e))?,
        requests: args.get_count("requests", 32).map_err(|e| anyhow!(e))?,
        hostile: args.get_usize("hostile", 0).map_err(|e| anyhow!(e))?,
        rate,
        sweep,
        sweep_requests: args.get_count("sweep-requests", 3).map_err(|e| anyhow!(e))?,
        out: Some(std::path::PathBuf::from(args.get_or("out", "BENCH_serve.json"))),
    };
    let report = deepcabac::serve::loadgen::run(&opts)?;
    println!(
        "{} clients x {} requests ({}): {} ok / {} failed, p50 {:.2} ms, p99 {:.2} ms, \
         p999 {:.2} ms, {:.0} req/s, {}",
        opts.clients,
        opts.requests,
        match opts.rate {
            Some(r) => format!("open loop, {r} req/s offered"),
            None => "closed loop".to_string(),
        },
        report.total_requests - report.failures,
        report.failures,
        report.p50_ms,
        report.p99_ms,
        report.p999_ms,
        report.throughput_rps,
        human_bytes(report.bytes_transferred as usize),
    );
    if report.failures > 0 {
        let t = &report.failure_taxonomy;
        println!(
            "failure taxonomy: {} connect-refused, {} timeout, {} reset, \
             {} malformed-response, {} http-error, {} shed, {} other",
            t.connect_refused,
            t.timeout,
            t.reset,
            t.malformed_response,
            t.http_error,
            t.shed,
            t.other,
        );
    }
    if opts.hostile > 0 {
        let i = &report.injected;
        println!(
            "injected ({} hostile threads): {} dribble, {} slowloris, {} disconnect, \
             {} stalled-reader; {} unexpected server reactions",
            opts.hostile, i.dribble, i.slowloris, i.disconnect, i.stalled_reader, i.unexpected,
        );
    }
    if let Some(p) = &report.progressive {
        println!(
            "time-to-first-usable-tier ({} progressive models, {} probes each): \
             base tier p50 {:.2} ms / p99 {:.2} ms ({}) vs full p50 {:.2} ms / \
             p99 {:.2} ms ({})",
            p.models,
            p.probes,
            p.base_p50_ms,
            p.base_p99_ms,
            human_bytes(p.base_bytes as usize),
            p.full_p50_ms,
            p.full_p99_ms,
            human_bytes(p.full_bytes as usize),
        );
    }
    for p in &report.connection_scaling {
        println!(
            "scaling {} conns: {} established, {} ok / {} failed / {} shed, \
             reused {} / reconnects {}, p50 {:.2} ms, p99 {:.2} ms, p999 {:.2} ms{}",
            p.connections,
            p.established,
            p.ok,
            p.failures,
            p.shed,
            p.reused,
            p.reconnects,
            p.p50_ms,
            p.p99_ms,
            p.p999_ms,
            match p.ttfut_ms {
                Some(t) => format!(", ttfut {t:.2} ms"),
                None => String::new(),
            },
        );
    }
    if let Some(out) = &opts.out {
        println!("wrote {out:?}");
    }
    anyhow::ensure!(
        report.failures == 0,
        "{} healthy-client requests failed",
        report.failures
    );
    anyhow::ensure!(
        report.injected.unexpected == 0,
        "{} hostile sessions got reactions outside their contract",
        report.injected.unexpected
    );
    Ok(())
}

/// Parse `--connections-sweep` lists like "1,64,1k,10k" (a `k` suffix
/// multiplies by 1000).
fn parse_connection_counts(list: &str) -> Result<Vec<usize>> {
    let mut counts = Vec::new();
    for part in list.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let (digits, mult) = match part.strip_suffix(['k', 'K']) {
            Some(d) => (d, 1000usize),
            None => (part, 1usize),
        };
        let n: usize = digits
            .parse()
            .map_err(|_| anyhow!("bad --connections-sweep entry {part:?}"))?;
        anyhow::ensure!(n > 0, "--connections-sweep entries must be positive, got {part:?}");
        counts.push(n * mult);
    }
    anyhow::ensure!(!counts.is_empty(), "--connections-sweep needs at least one count");
    Ok(counts)
}

/// Structure-aware fuzzing (the CI `fuzz-smoke` entry point): replay the
/// checked-in crasher corpus, then run fixed-seed generate-and-mutate
/// batches per target. Exits nonzero on any invariant violation, after
/// writing minimized reproducers to `--artifacts` for triage / corpus
/// promotion.
fn cmd_fuzz(args: &Args) -> Result<()> {
    use deepcabac::fuzz::{fuzz_target, replay_corpus, Budgets, Crash, TargetKind};

    let targets: Vec<TargetKind> = match args.get_or("target", "all") {
        "all" => TargetKind::all().to_vec(),
        "container" => vec![TargetKind::Container],
        "stream" => vec![TargetKind::Stream],
        "http" => vec![TargetKind::Http],
        "range" => vec![TargetKind::Range],
        "encoder" => vec![TargetKind::Encoder],
        "delta_apply" => vec![TargetKind::DeltaApply],
        other => bail!(
            "--target must be container|stream|http|range|encoder|delta_apply|all, got {other:?}"
        ),
    };
    let cases = args.get_count("cases", 256).map_err(|e| anyhow!(e))?;
    let seed = args.get_usize("seed", 42).map_err(|e| anyhow!(e))? as u64;
    let corpus = std::path::PathBuf::from(args.get_or("corpus", "fuzz_corpus"));
    let artifacts = args.get("artifacts").map(std::path::PathBuf::from);
    let budgets = Budgets::default();

    let mut all_crashes: Vec<Crash> = Vec::new();

    let (rstats, rcrashes) = replay_corpus(&corpus, &budgets)?;
    println!(
        "corpus replay ({corpus:?}): {} cases, {} crashes{}",
        rstats.cases,
        rstats.crashes,
        if rstats.alloc_metered { "" } else { " (alloc unmetered)" },
    );
    all_crashes.extend(rcrashes);

    if args.has("evolve") {
        all_crashes.extend(cmd_fuzz_evolve(args, &targets, cases, seed, &corpus, &budgets)?);
        return finish_fuzz(all_crashes, artifacts.as_deref());
    }

    for &t in &targets {
        let (stats, crashes) = fuzz_target(t, cases, seed, &budgets);
        println!(
            "{:<9} {} cases: {} crashes, {} survived prefix ({:.0}%), {} accepted",
            t.as_str(),
            stats.cases,
            stats.crashes,
            stats.survived_prefix,
            stats.survival_ratio() * 100.0,
            stats.accepted,
        );
        // the coverage proxy from the structure-aware mutator's contract:
        // most mutants must get past the container prelude into
        // layer/chunk handling, or the fuzzer has regressed into a
        // magic-check bouncer
        if t == TargetKind::Container && stats.crashes == 0 {
            anyhow::ensure!(
                stats.survival_ratio() >= 0.5,
                "container prelude survival {:.0}% < 50% — mutator lost its structure awareness",
                stats.survival_ratio() * 100.0
            );
        }
        all_crashes.extend(crashes);
    }

    finish_fuzz(all_crashes, artifacts.as_deref())
}

/// Shared fuzz epilogue: dump minimized reproducers (to `--artifacts`
/// when given, stdout otherwise) and exit nonzero on any violation.
fn finish_fuzz(
    all_crashes: Vec<deepcabac::fuzz::Crash>,
    artifacts: Option<&std::path::Path>,
) -> Result<()> {
    if !all_crashes.is_empty() {
        if let Some(dir) = artifacts {
            std::fs::create_dir_all(dir)?;
            for (i, c) in all_crashes.iter().enumerate() {
                let p = dir.join(format!("crash_{:03}_{}.bin", i, c.target.as_str()));
                std::fs::write(&p, &c.input)?;
                println!("wrote {p:?} ({}): {}", human_bytes(c.input.len()), c.kind);
            }
        } else {
            for c in &all_crashes {
                println!("crash [{}] ({} bytes): {}", c.target.as_str(), c.input.len(), c.kind);
            }
        }
        bail!("{} invariant violations (minimized reproducers above)", all_crashes.len());
    }
    println!("fuzz: all invariants held");
    Ok(())
}

/// The `fuzz --evolve` mode: per target, seed the pool from the on-disk
/// corpus, run the coverage-guided evolution loop, compare against the
/// same-budget fixed-seed batch, print the edge-discovery curve, write
/// promoted finds to `--artifacts`, and emit `BENCH_fuzz.json`
/// (`--json`). Returns the crashes found (the caller turns any into a
/// nonzero exit). `--max-time` caps each *target's* loop in seconds;
/// `--cases` caps its executions — whichever fires first.
fn cmd_fuzz_evolve(
    args: &Args,
    targets: &[deepcabac::fuzz::TargetKind],
    cases: usize,
    seed: u64,
    corpus: &std::path::Path,
    budgets: &deepcabac::fuzz::Budgets,
) -> Result<Vec<deepcabac::fuzz::Crash>> {
    use deepcabac::fuzz::{batch_coverage, corpus_groups, cov, evolve_target, EvolveCfg};

    let max_time = args.get_usize("max-time", 0).map_err(|e| anyhow!(e))? as u64;
    let json_path = args.get_or("json", "BENCH_fuzz.json");
    let artifacts = args.get("artifacts").map(std::path::PathBuf::from);
    if !cov::enabled() {
        println!(
            "note: built without --features fuzz-cov — no edges will be recorded, \
             evolution degrades to uniform seed scheduling"
        );
    }

    let mut crashes = Vec::new();
    let mut target_rows: Vec<Json> = Vec::new();
    let mut alloc_metered = true;
    for &t in targets {
        // the seed pool: every checked-in corpus file for a group that
        // replays against this target, in sorted (deterministic) order
        let mut initial: Vec<Vec<u8>> = Vec::new();
        for (sub, group) in corpus_groups() {
            if !group.contains(&t) {
                continue;
            }
            let dir = corpus.join(sub);
            if !dir.is_dir() {
                continue;
            }
            let mut paths: Vec<_> = std::fs::read_dir(&dir)?
                .filter_map(|e| e.ok().map(|e| e.path()))
                .filter(|p| p.is_file())
                .collect();
            paths.sort();
            for p in paths {
                initial.push(std::fs::read(&p)?);
            }
        }
        let cfg = EvolveCfg {
            seed,
            cases,
            max_millis: max_time * 1000,
            budgets: *budgets,
            ..EvolveCfg::default()
        };
        let report = evolve_target(t, &cfg, &initial);
        // same-budget comparison: the plain fixed-seed batch loop's
        // unique edges over the executions evolve actually performed
        let batch_edges = batch_coverage(t, report.cases, seed, budgets);
        alloc_metered &= report.alloc_metered;
        println!(
            "{:<11} evolve: {} execs ({:.0}/s), {} edges (batch {}), {} promoted, corpus {} -> {}, {} crashes",
            t.as_str(),
            report.cases,
            report.execs_per_sec,
            report.unique_edges,
            batch_edges,
            report.promoted,
            initial.len(),
            report.corpus_len,
            report.crashes.len(),
        );
        // the discovery curve, decimated to ~10 points for the log
        let step = (report.discovery.len() / 10).max(1);
        let curve: Vec<String> = report
            .discovery
            .iter()
            .step_by(step)
            .chain(
                report
                    .discovery
                    .last()
                    .filter(|_| (report.discovery.len() - 1) % step != 0),
            )
            .map(|(i, e)| format!("{i}:{e}"))
            .collect();
        println!("            edges over execs: {}", curve.join(" "));
        if let Some(dir) = &artifacts {
            std::fs::create_dir_all(dir)?;
            for (i, input) in report.promoted_inputs.iter().enumerate() {
                let p = dir.join(format!("promoted_{}_{:03}.bin", t.as_str(), i));
                std::fs::write(&p, input)?;
            }
            if !report.promoted_inputs.is_empty() {
                println!(
                    "            wrote {} promoted finds to {dir:?}",
                    report.promoted_inputs.len()
                );
            }
        }
        target_rows.push(json::obj(vec![
            ("target", json::s(t.as_str())),
            ("mode", json::s("evolve")),
            ("cases", json::num(report.cases as f64)),
            ("execs_per_s", json::num(report.execs_per_sec)),
            ("unique_edges", json::num(report.unique_edges as f64)),
            ("batch_unique_edges", json::num(batch_edges as f64)),
            ("corpus_size", json::num(report.corpus_len as f64)),
            ("promoted", json::num(report.promoted as f64)),
            ("crashes", json::num(report.crashes.len() as f64)),
            (
                "discovery",
                json::arr(
                    report
                        .discovery
                        .iter()
                        .map(|&(i, e)| {
                            json::arr(vec![json::num(i as f64), json::num(e as f64)])
                        })
                        .collect(),
                ),
            ),
        ]));
        crashes.extend(report.crashes);
    }
    let j = json::obj(vec![
        ("bench", json::s("fuzz")),
        ("seed", json::num(seed as f64)),
        ("cov_enabled", json::boolean(cov::enabled())),
        ("alloc_metered", json::boolean(alloc_metered)),
        ("targets", json::arr(target_rows)),
    ]);
    std::fs::write(json_path, j.to_string_pretty())?;
    println!("wrote {json_path}");
    Ok(crashes)
}
