//! DeepCABAC CLI entry point — see `deepcabac --help` / [`deepcabac::cli::USAGE`].

use anyhow::{anyhow, bail, Context, Result};
use byteorder::ByteOrder as _;
use deepcabac::app;
use deepcabac::cli::{Args, USAGE};
use deepcabac::codec::{decode_levels, CodecConfig, LevelEncoder};
use deepcabac::coordinator::{
    compress_model, pipeline::decompress, sweep_s, sweep_s_auto, CompressionSpec,
    SweepOptions, SweepResult,
};
use deepcabac::model::CompressedModel;
use deepcabac::report::{human_bytes, Table};
use deepcabac::runtime::Runtime;
use deepcabac::synth::Arch;
use deepcabac::tensor::npy;
use deepcabac::util::json::{self, Json};
use deepcabac::util::Timer;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() || argv[0] == "--help" || argv[0] == "help" {
        print!("{USAGE}");
        return;
    }
    let args = match Args::parse(&argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{USAGE}");
            std::process::exit(2);
        }
    };
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(args: &Args) -> Result<()> {
    match args.cmd.as_str() {
        "table1" => cmd_table1(args),
        "compress" => cmd_compress(args),
        "compress-npy" => cmd_compress_npy(args),
        "decompress" => cmd_decompress(args),
        "eval" => cmd_eval(args),
        "anatomy" => cmd_anatomy(args),
        "sweep" => cmd_sweep(args),
        "synth" => cmd_synth(args),
        "serve" => cmd_serve(args),
        "fetch" => cmd_fetch(args),
        "loadgen" => cmd_loadgen(args),
        other => bail!("unknown subcommand {other:?}\n\n{USAGE}"),
    }
}

fn base_spec(args: &Args) -> Result<CompressionSpec> {
    let chunks = args.get_count("chunks", 1).map_err(|e| anyhow!(e))?;
    if chunks > deepcabac::model::container::MAX_CHUNKS {
        bail!("--chunks must be in 1..={}", deepcabac::model::container::MAX_CHUNKS);
    }
    Ok(CompressionSpec {
        lambda_scale: args.get_f32("lambda-scale", 0.05).map_err(|e| anyhow!(e))?,
        chunks: chunks as u32,
        ..Default::default()
    })
}

fn cmd_table1(args: &Args) -> Result<()> {
    let sweep_points = args.get_count("sweep", 17).map_err(|e| anyhow!(e))?;
    let workers = args.get_count("workers", 1).map_err(|e| anyhow!(e))?;
    let scale = args.get_usize("scale", 8).map_err(|e| anyhow!(e))?;
    let with_eval = !args.has("no-eval");
    let spec = base_spec(args)?;
    let s_grid = deepcabac::coordinator::sweep::default_s_grid(sweep_points);

    let mut table = Table::new(&[
        "Model", "Dataset", "Org.acc(top1)", "Org.size", "Spars.[%]",
        "Comp.ratio[%]", "Acc.after", "best S",
    ]);
    for name in app::SMALL_MODELS {
        eprintln!("[table1] {name} ...");
        let row = app::table1_small_row(name, &s_grid, &spec, workers, with_eval)?;
        table.row(vec![
            row.model.clone(),
            row.dataset.clone(),
            format!("{:.2}", row.org_metric * metric_scale(&row.model)),
            human_bytes(row.org_bytes),
            format!("{:.2}", row.sparsity_pct),
            format!("{:.2}", row.ratio_pct),
            row.metric_after
                .map(|m| format!("{:.2}", m * metric_scale(&row.model)))
                .unwrap_or_else(|| "n/a".into()),
            row.best_s.to_string(),
        ]);
    }
    if args.has("large") {
        for arch in [Arch::Vgg16, Arch::ResNet50, Arch::MobileNetV1] {
            eprintln!("[table1] {} (synthetic, 1/{scale} scale) ...", arch.name());
            let row =
                app::table1_large_row(arch, scale, &s_grid, &spec, workers, 42)?;
            table.row(vec![
                row.model.clone(),
                row.dataset.clone(),
                "n/a".into(),
                human_bytes(row.org_bytes),
                format!("{:.2}", row.sparsity_pct),
                format!("{:.2}", row.ratio_pct),
                "n/a".into(),
                row.best_s.to_string(),
            ]);
        }
    }
    println!("{}", table.render());
    Ok(())
}

/// classifiers report %, fcae reports PSNR dB
fn metric_scale(model: &str) -> f64 {
    if model == "fcae" {
        1.0
    } else {
        100.0
    }
}

fn cmd_compress(args: &Args) -> Result<()> {
    let name = args.get("model").context("--model required")?;
    let out = args.get("out").context("--out required")?;
    let workers = args.get_count("workers", 1).map_err(|e| anyhow!(e))?;
    let model = app::load_model(name)?;
    let mut spec = base_spec(args)?;
    let (compressed, report) = if let Some(s) = args.get("s") {
        spec.s = s.parse().context("--s expects an integer")?;
        compress_model(&model, &spec, workers)
    } else {
        let points = args.get_count("sweep", 17).map_err(|e| anyhow!(e))?;
        let grid = deepcabac::coordinator::sweep::default_s_grid(points);
        if args.has("per-layer") {
            let (c, r, chosen) =
                deepcabac::coordinator::sweep::sweep_s_per_layer(&model, &grid, &spec)?;
            for (l, s) in &chosen {
                eprintln!("  {l}: S = {s}");
            }
            (c, r)
        } else {
            sweep_s(&model, &grid, &spec, workers)?.best
        }
    };
    std::fs::write(out, compressed.serialize())?;
    println!(
        "{name}: {} -> {} ({:.2}% of original, x{:.1}) S={}{}",
        human_bytes(report.raw_bytes),
        human_bytes(report.compressed_bytes),
        report.ratio_percent(),
        report.factor(),
        compressed.layers.first().map(|l| l.s_param).unwrap_or(0),
        if compressed.is_chunked() {
            format!(" chunks={}", report.total_chunks())
        } else {
            String::new()
        },
    );
    Ok(())
}

/// Compress an arbitrary `.npy` weight tensor from disk (σ optional:
/// without it the unweighted η = 1 ablation path is used).
fn cmd_compress_npy(args: &Args) -> Result<()> {
    let input = std::path::PathBuf::from(args.get("in").context("--in required")?);
    let out = args.get("out").context("--out required")?;
    let (shape, data) = npy::read_npy_f32(&input)?;
    deepcabac::tensor::validate_finite(&format!("{input:?} weights"), &data)?;
    let (sigmas, weighted) = match args.get("sigma") {
        Some(p) => {
            let (ss, sd) = npy::read_npy_f32(std::path::Path::new(p))?;
            anyhow::ensure!(ss == shape, "sigma shape {ss:?} != weight shape {shape:?}");
            deepcabac::tensor::validate_finite(&format!("{p:?} sigma"), &sd)?;
            (sd, true)
        }
        None => (vec![0.05f32; data.len()], false),
    };
    let mut spec = base_spec(args)?;
    spec.weighted = weighted;
    spec.s = args.get_usize("s", 64).map_err(|e| anyhow!(e))? as u32;
    let name = input.file_stem().and_then(|s| s.to_str()).unwrap_or("tensor");
    let (layer, report) =
        deepcabac::coordinator::compress_tensor(name, &shape, &data, &sigmas, &[], &spec);
    let container = CompressedModel { name: name.into(), layers: vec![layer] };
    std::fs::write(out, container.serialize())?;
    println!(
        "{name}: {} -> {} ({:.3} bits/weight, density {:.2}%)",
        human_bytes(data.len() * 4),
        human_bytes(report.payload_bytes),
        report.bits_per_weight(),
        report.density() * 100.0,
    );
    Ok(())
}

fn cmd_decompress(args: &Args) -> Result<()> {
    let input = args.get("in").context("--in required")?;
    let out_dir = std::path::PathBuf::from(args.get("out-dir").context("--out-dir required")?);
    std::fs::create_dir_all(&out_dir)?;
    let bytes = std::fs::read(input)?;
    let compressed = CompressedModel::deserialize(&bytes)?;
    let tensors = decompress(&compressed);
    for (layer, t) in compressed.layers.iter().zip(&tensors) {
        let path = out_dir.join(format!("{}.w.npy", layer.name));
        npy::write_npy_f32(&path, &t.shape, &t.data)?;
        println!("wrote {path:?} {:?}", t.shape);
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let name = args.get("model").context("--model required")?;
    let model = app::load_model(name)?;
    let rt = Runtime::cpu()?;
    let result = if let Some(path) = args.get("compressed") {
        let compressed = CompressedModel::deserialize(&std::fs::read(path)?)?;
        app::evaluate_compressed(&rt, &model, &compressed)?
    } else {
        app::evaluate_original(&rt, &model)?
    };
    let unit = if model.manifest.task == "classify" { "top-1" } else { "PSNR dB" };
    println!(
        "{name}: {:.4} {unit} over {} samples ({:.2}s on {})",
        result.metric,
        result.n_samples,
        result.exec_time_s,
        rt.platform(),
    );
    Ok(())
}

fn cmd_anatomy(args: &Args) -> Result<()> {
    let levels: Vec<i32> = args
        .get_or("levels", "0,3,0,0,-1,14,0,1")
        .split(',')
        .map(|t| t.trim().parse::<i32>().context("bad level"))
        .collect::<Result<_>>()?;
    println!("DeepCABAC binarization trace (paper figure 1)\n");
    let cfg = CodecConfig::default();
    let mut enc = LevelEncoder::new(cfg);
    println!("{:<8} {:<28} {}", "level", "bins (sig/sign/gr../rem)", "ctx p(sig=1) before");
    for &l in &levels {
        let p_sig = enc.ctxs.sig
            [deepcabac::codec::ContextSet::sig_ctx_index(&cfg, enc.prev_sig())]
        .p_one();
        println!("{:<8} {:<28} {:.3}", l, describe_bins(l, &cfg), p_sig);
        enc.encode_level(l);
    }
    let n = levels.len();
    let payload = enc.finish();
    println!(
        "\n{} levels -> {} bytes ({:.2} bits/level); raw f32 would be {} bytes",
        n,
        payload.len(),
        payload.len() as f64 * 8.0 / n as f64,
        n * 4
    );
    let dec = decode_levels(&payload, n, cfg);
    println!("decode roundtrip: {}", if dec == levels { "OK" } else { "MISMATCH" });
    Ok(())
}

fn describe_bins(level: i32, cfg: &CodecConfig) -> String {
    if level == 0 {
        return "sig=0".into();
    }
    let mut s = format!("sig=1 sign={}", (level < 0) as u8);
    let abs = level.unsigned_abs();
    for i in 1..=cfg.n_abs_flags.min(abs + 1) {
        if abs > i {
            s.push_str(&format!(" gr{i}=1"));
        } else {
            s.push_str(&format!(" gr{i}=0"));
            return s;
        }
    }
    s.push_str(&format!(" rem={}", abs - cfg.n_abs_flags - 1));
    s
}

/// The S-sweep subcommand: drive the parallel incremental engine
/// (coarse-to-fine refinement with early abandonment, or `--sweep-exhaustive`
/// for all 257 points) and emit the rate–distortion frontier as
/// `BENCH_sweep.json` (+ optional CSV / best-container output).
fn cmd_sweep(args: &Args) -> Result<()> {
    let points = args.get_count("points", 17).map_err(|e| anyhow!(e))?;
    let workers = args.get_count("workers", 1).map_err(|e| anyhow!(e))?;
    let opts = SweepOptions {
        points,
        workers,
        exhaustive: args.has("sweep-exhaustive"),
        abandon: !args.has("no-abandon"),
    };
    let spec = base_spec(args)?;
    let (name, model) = if let Some(m) = args.get("model") {
        (m.to_string(), app::load_model(m)?)
    } else if let Some(a) = args.get("arch") {
        let arch = Arch::parse(a).context("--arch must be vgg16|resnet50|mobilenet")?;
        let scale = args.get_count("scale", 8).map_err(|e| anyhow!(e))?;
        let seed = args.get_usize("seed", 42).map_err(|e| anyhow!(e))? as u64;
        (
            arch.name().to_string(),
            deepcabac::synth::generate(arch, scale, seed).to_model(),
        )
    } else {
        bail!("sweep needs --model NAME or --arch vgg16|resnet50|mobilenet");
    };

    let res = sweep_s_auto(&model, &opts, &spec)?;
    let best_s = res.best.0.layers.first().map(|l| l.s_param).unwrap_or(0);
    println!(
        "{name}: best S = {best_s} -> {} ({:.2}% of original, x{:.1}); \
         {} probes in {} rounds, {} abandoned, {:.2}s ({} workers)",
        human_bytes(res.best.1.compressed_bytes),
        res.best.1.ratio_percent(),
        res.best.1.factor(),
        res.stats.probes_total,
        res.stats.rounds,
        res.stats.probes_abandoned,
        res.stats.wall_s,
        workers,
    );

    // serial reference (same schedule, one worker): wall-clock baseline
    // for the fan-out, and a live check that the parallel engine selects
    // a byte-identical container
    let wall_serial = if args.has("compare-serial") {
        let t = Timer::new();
        let serial = sweep_s_auto(&model, &SweepOptions { workers: 1, ..opts }, &spec)?;
        let wall = t.elapsed_s();
        anyhow::ensure!(
            serial.best.0.serialize() == res.best.0.serialize(),
            "parallel sweep selected a different container than the \
             serial sweep (worker-count determinism violated)"
        );
        println!(
            "serial reference: {:.2}s (parallel speedup x{:.2})",
            wall,
            wall / res.stats.wall_s.max(1e-9),
        );
        Some(wall)
    } else {
        None
    };

    let json_path = args.get_or("json", "BENCH_sweep.json");
    std::fs::write(json_path, sweep_to_json(&name, &opts, &res, wall_serial).to_string_pretty())?;
    println!("wrote {json_path}");

    if let Some(csv_path) = args.get("csv") {
        let rows: Vec<Vec<String>> = res
            .points
            .iter()
            .map(|p| {
                vec![
                    p.s.to_string(),
                    p.compressed_bytes.to_string(),
                    format!("{:.6}", p.density),
                    format!("{:.6e}", p.distortion),
                    (p.abandoned as u8).to_string(),
                    format!("{:.3}", p.wall_s * 1e3),
                ]
            })
            .collect();
        let csv = deepcabac::report::to_csv(
            &["S", "bytes", "density", "distortion", "abandoned", "wall_ms"],
            &rows,
        );
        std::fs::write(csv_path, &csv)?;
        println!("wrote {csv_path}");
    }

    if let Some(out) = args.get("out") {
        std::fs::write(out, res.best.0.serialize())?;
        println!("wrote {out}");
    }
    Ok(())
}

fn sweep_to_json(
    name: &str,
    opts: &SweepOptions,
    res: &SweepResult,
    wall_serial: Option<f64>,
) -> Json {
    let best_s = res.best.0.layers.first().map(|l| l.s_param).unwrap_or(0);
    let points: Vec<Json> = res
        .points
        .iter()
        .map(|p| {
            json::obj(vec![
                ("s", json::num(p.s as f64)),
                ("bytes", json::num(p.compressed_bytes as f64)),
                ("density", json::num(p.density)),
                ("distortion", json::num(p.distortion)),
                ("abandoned", Json::Bool(p.abandoned)),
                ("wall_ms", json::num(p.wall_s * 1e3)),
            ])
        })
        .collect();
    let mut fields = vec![
        ("bench", json::s("sweep")),
        ("model", json::s(name)),
        ("workers", json::num(opts.workers as f64)),
        ("points_per_round", json::num(opts.points as f64)),
        ("exhaustive", Json::Bool(opts.exhaustive)),
        ("abandon", Json::Bool(opts.abandon)),
        ("rounds", json::num(res.stats.rounds as f64)),
        ("probes_total", json::num(res.stats.probes_total as f64)),
        ("probes_abandoned", json::num(res.stats.probes_abandoned as f64)),
        ("best_s", json::num(best_s as f64)),
        ("best_bytes", json::num(res.best.1.compressed_bytes as f64)),
        ("raw_bytes", json::num(res.best.1.raw_bytes as f64)),
        ("wall_s", json::num(res.stats.wall_s)),
        ("points", json::arr(points)),
    ];
    if let Some(w) = wall_serial {
        fields.push(("wall_s_serial", json::num(w)));
    }
    json::obj(fields)
}

fn cmd_synth(args: &Args) -> Result<()> {
    let arch = Arch::parse(args.get_or("arch", "vgg16"))
        .context("--arch must be vgg16|resnet50|mobilenet")?;
    let scale = args.get_usize("scale", 8).map_err(|e| anyhow!(e))?;
    let spec = CompressionSpec {
        s: args.get_usize("s", 64).map_err(|e| anyhow!(e))? as u32,
        ..base_spec(args)?
    };
    let row = app::table1_large_row(arch, scale, &[spec.s], &spec, 1, 42)?;
    println!(
        "{} (1/{scale} scale): {} raw, density {:.2}%, compressed {} ({:.2}%, x{:.1})",
        arch.name(),
        human_bytes(row.org_bytes),
        row.sparsity_pct,
        human_bytes(row.report.compressed_bytes),
        row.ratio_pct,
        row.report.factor(),
    );
    if let Some(out) = args.get("out") {
        std::fs::write(out, row.compressed.serialize())?;
        println!("wrote {out}");
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let opts = deepcabac::serve::ServeOptions {
        dir: std::path::PathBuf::from(args.get("dir").context("--dir required")?),
        addr: args.get_or("addr", "127.0.0.1:8080").to_string(),
        cache_bytes: args.get_usize("cache-mb", 64).map_err(|e| anyhow!(e))? << 20,
        workers: args
            .get_count(
                "workers",
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
            )
            .map_err(|e| anyhow!(e))?,
    };
    let handle = deepcabac::serve::server::start(opts.clone())?;
    // the smoke script greps this exact line for the ephemeral port
    println!("listening on http://{}", handle.addr());
    println!(
        "serving {:?} ({} workers, {} cache)",
        opts.dir,
        opts.workers,
        human_bytes(opts.cache_bytes),
    );
    use std::io::Write as _;
    std::io::stdout().flush().ok();
    // foreground server: block until killed
    loop {
        std::thread::park();
    }
}

/// Layer names from a remote container (or response header) are
/// attacker-controlled: reduce them to a single safe path component so
/// `--out-dir` writes can never traverse outside the output directory.
fn safe_file_stem(name: &str) -> String {
    let cleaned: String = name
        .chars()
        .map(|c| {
            if c.is_alphanumeric() || matches!(c, '-' | '_' | '.') { c } else { '_' }
        })
        .collect();
    let cleaned = cleaned.trim_matches('.').to_string();
    if cleaned.is_empty() {
        "layer".to_string()
    } else {
        cleaned
    }
}

fn cmd_fetch(args: &Args) -> Result<()> {
    use deepcabac::serve::http;
    use deepcabac::serve::{StreamDecoder, StreamEvent};

    let url = args.get("url").context("--url required (http://HOST:PORT/models/NAME)")?;
    let (addr, path) = http::parse_url(url)?;
    let path = path.trim_end_matches('/').to_string();
    let out_dir = args.get("out-dir").map(std::path::PathBuf::from);
    if let Some(d) = &out_dir {
        std::fs::create_dir_all(d)?;
    }

    if let Some(layer) = args.get("layer") {
        // random access: one layer's server-side-decoded weights
        let resp = http::get(&addr, &format!("{path}/layers/{layer}/weights"), None)?;
        anyhow::ensure!(resp.status == 200, "HTTP {} fetching layer {layer}", resp.status);
        let dims: Vec<usize> = resp
            .header("x-dims")
            .unwrap_or("")
            .split(',')
            .filter_map(|d| d.parse().ok())
            .collect();
        let name = resp.header("x-layer-name").unwrap_or(layer).to_string();
        anyhow::ensure!(resp.body.len() % 4 == 0, "weight body not f32-aligned");
        let mut weights = vec![0f32; resp.body.len() / 4];
        byteorder::LittleEndian::read_f32_into(&resp.body, &mut weights);
        println!(
            "{name}: {} weights, dims {dims:?}, {} (cache {})",
            weights.len(),
            human_bytes(resp.body.len()),
            resp.header("x-cache").unwrap_or("?"),
        );
        if let Some(d) = &out_dir {
            let shape = if dims.is_empty() { vec![weights.len()] } else { dims };
            let p = d.join(format!("{}.w.npy", safe_file_stem(&name)));
            npy::write_npy_f32(&p, &shape, &weights)?;
            println!("wrote {p:?}");
        }
        return Ok(());
    }

    // whole container: drive the streaming decoder straight off the socket
    let mut dec = StreamDecoder::new();
    let mut layers = Vec::new();
    let (status, _headers, err_body) = http::get_streaming(&addr, &path, None, &mut |chunk| {
        for ev in dec.feed(chunk)? {
            match ev {
                StreamEvent::Start { model, version, n_layers } => {
                    eprintln!("[fetch] {model} v{version}: {n_layers} layers incoming");
                }
                StreamEvent::Chunk { layer, chunk, n_chunks, .. } => {
                    if n_chunks > 1 {
                        eprintln!("[fetch]   layer {layer}: chunk {}/{n_chunks}", chunk + 1);
                    }
                }
                StreamEvent::Layer(l) => {
                    eprintln!(
                        "[fetch] layer {} ({}): {} weights decoded mid-stream",
                        l.index,
                        l.name,
                        l.n_weights
                    );
                    layers.push(*l);
                }
                StreamEvent::End => {}
            }
        }
        Ok(())
    })?;
    anyhow::ensure!(
        status == 200,
        "HTTP {status} fetching {url}: {}",
        String::from_utf8_lossy(&err_body)
    );
    dec.finish()?;
    println!(
        "{}: {} layers, {} container bytes streamed",
        url,
        layers.len(),
        dec.bytes_consumed(),
    );
    if let Some(d) = &out_dir {
        for l in &layers {
            let p = d.join(format!("{}.w.npy", safe_file_stem(&l.name)));
            npy::write_npy_f32(&p, &l.dims, &l.weights)?;
            println!("wrote {p:?}");
        }
    }
    Ok(())
}

fn cmd_loadgen(args: &Args) -> Result<()> {
    let opts = deepcabac::serve::loadgen::LoadgenOptions {
        url: args.get("url").context("--url required (http://HOST:PORT)")?.to_string(),
        clients: args.get_count("clients", 8).map_err(|e| anyhow!(e))?,
        requests: args.get_count("requests", 32).map_err(|e| anyhow!(e))?,
        out: Some(std::path::PathBuf::from(args.get_or("out", "BENCH_serve.json"))),
    };
    let report = deepcabac::serve::loadgen::run(&opts)?;
    println!(
        "{} clients x {} requests: {} ok / {} failed, p50 {:.2} ms, p99 {:.2} ms, {:.0} req/s, {}",
        opts.clients,
        opts.requests,
        report.total_requests - report.failures,
        report.failures,
        report.p50_ms,
        report.p99_ms,
        report.throughput_rps,
        human_bytes(report.bytes_transferred as usize),
    );
    if let Some(out) = &opts.out {
        println!("wrote {out:?}");
    }
    anyhow::ensure!(report.failures == 0, "{} requests failed", report.failures);
    Ok(())
}
