//! Wall-clock timing helper used by the bench harness and the pipeline
//! metrics.

use std::time::Instant;

#[derive(Debug)]
pub struct Timer {
    start: Instant,
}

impl Default for Timer {
    fn default() -> Self {
        Self::new()
    }
}

impl Timer {
    pub fn new() -> Self {
        Self { start: Instant::now() }
    }

    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed_s() * 1e3
    }

    pub fn reset(&mut self) {
        self.start = Instant::now();
    }
}
