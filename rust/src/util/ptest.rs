//! Mini property-testing harness (proptest is not in the offline
//! registry). Deterministic: each case derives from a SplitMix64 stream
//! seeded by the case index, so failures are reproducible by index. On
//! failure the harness retries the case with geometrically shrunk size
//! hints and reports the smallest failing seed it found.

use super::rng::SplitMix64;

/// Configuration for a property run.
#[derive(Debug, Clone)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
    /// Upper bound passed to generators as the "size" hint.
    pub max_size: usize,
}

impl Default for Config {
    fn default() -> Self {
        Self { cases: 128, seed: 0xDEC0DE, max_size: 2048 }
    }
}

/// A generation context handed to the property closure.
pub struct Gen<'a> {
    pub rng: &'a mut SplitMix64,
    pub size: usize,
}

impl<'a> Gen<'a> {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.below((hi - lo + 1) as u64) as usize
    }

    pub fn i32_in(&mut self, lo: i32, hi: i32) -> i32 {
        lo + self.rng.below((hi as i64 - lo as i64 + 1) as u64) as i32
    }

    pub fn f32_normal(&mut self, std: f32) -> f32 {
        (self.rng.normal() as f32) * std
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    /// Vector of i32 levels with a controllable sparsity/spread — the shape
    /// of data the weight codec sees.
    pub fn levels(&mut self) -> Vec<i32> {
        let n = self.usize_in(0, self.size);
        let p_zero = self.rng.next_f64();
        let spread = 1 + self.rng.below(200) as i32;
        (0..n)
            .map(|_| {
                if self.rng.next_f64() < p_zero {
                    0
                } else {
                    let mag = 1 + self.rng.below(spread as u64) as i32;
                    if self.rng.next_u64() & 1 == 0 {
                        mag
                    } else {
                        -mag
                    }
                }
            })
            .collect()
    }

    pub fn f32_vec(&mut self, std: f32) -> Vec<f32> {
        let n = self.usize_in(0, self.size);
        (0..n).map(|_| self.f32_normal(std)).collect()
    }

    pub fn bytes(&mut self) -> Vec<u8> {
        let n = self.usize_in(0, self.size);
        (0..n).map(|_| self.rng.next_u64() as u8).collect()
    }
}

/// Drive `decode` over a battery of hostile inputs derived from one
/// `valid` exemplar: random garbage of assorted sizes, truncations, and
/// single-bit corruptions. The closure must *return* on every input (Ok
/// or Err alike) — a panic propagates and fails the calling test. This
/// is the shared dumb-random driver used by `tests/fuzz_robustness.rs`
/// and complemented by the structure-aware engine in [`crate::fuzz`],
/// which mutates field-by-field instead of bit-by-bit.
pub fn hostile_inputs(valid: &[u8], rng: &mut SplitMix64, mut decode: impl FnMut(&[u8])) {
    // random garbage of many sizes
    for size in [0usize, 1, 2, 7, 64, 1024] {
        let buf: Vec<u8> = (0..size).map(|_| rng.next_u64() as u8).collect();
        decode(&buf);
    }
    // truncations
    for cut in [0usize, 1, 2, valid.len() / 2, valid.len().saturating_sub(1)] {
        decode(&valid[..cut.min(valid.len())]);
    }
    // bit flips
    for _ in 0..64 {
        if valid.is_empty() {
            break;
        }
        let mut buf = valid.to_vec();
        let pos = rng.below(buf.len() as u64) as usize;
        buf[pos] ^= 1 << rng.below(8);
        decode(&buf);
    }
}

/// Run `prop` over `cfg.cases` generated cases; panic with the failing
/// case index + seed on the first failure (after shrinking the size).
pub fn check<F>(cfg: Config, name: &str, mut prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        let seed = cfg.seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = SplitMix64::new(seed);
        let mut g = Gen { rng: &mut rng, size: cfg.max_size };
        if let Err(msg) = prop(&mut g) {
            // Shrink: retry with smaller size hints to find a smaller repro.
            let mut best = (cfg.max_size, msg.clone());
            let mut size = cfg.max_size / 2;
            while size >= 1 {
                let mut rng = SplitMix64::new(seed);
                let mut g = Gen { rng: &mut rng, size };
                match prop(&mut g) {
                    Err(m) => {
                        best = (size, m);
                        size /= 2;
                    }
                    Ok(()) => break,
                }
            }
            panic!(
                "property '{name}' failed at case {case} (seed {seed:#x}, min size {}): {}",
                best.0, best.1
            );
        }
    }
}

/// Shorthand with the default config.
pub fn quick<F>(name: &str, prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    check(Config::default(), name, prop);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        quick("reverse-reverse", |g| {
            let v = g.bytes();
            let mut r = v.clone();
            r.reverse();
            r.reverse();
            if r == v {
                Ok(())
            } else {
                Err("reverse^2 != id".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails'")]
    fn reports_failure() {
        quick("always-fails", |_g| Err("nope".into()));
    }

    #[test]
    fn levels_generator_hits_extremes() {
        // Over many cases we should see both all-zero and dense vectors.
        let mut saw_zeroish = false;
        let mut saw_dense = false;
        check(Config { cases: 64, ..Default::default() }, "gen-cover", |g| {
            let v = g.levels();
            if !v.is_empty() {
                let nz = v.iter().filter(|&&x| x != 0).count();
                let frac = nz as f64 / v.len() as f64;
                if frac < 0.2 {
                    saw_zeroish = true;
                }
                if frac > 0.8 {
                    saw_dense = true;
                }
            }
            Ok(())
        });
        assert!(saw_zeroish && saw_dense);
    }
}
