//! Minimal JSON parser + writer (serde is not in the offline registry).
//!
//! Supports the full JSON grammar minus exotic escapes (\u is decoded for
//! the BMP only), which is all the artifact manifests need.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing bytes at {}", p.i));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    /// Convenience: `obj.path("a.b.c")`.
    pub fn path(&self, dotted: &str) -> Option<&Json> {
        let mut cur = self;
        for part in dotted.split('.') {
            cur = cur.get(part)?;
        }
        Some(cur)
    }

    // -- writer -------------------------------------------------------------

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push_str("  ");
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{}", n);
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, e) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    e.write(out, indent + 1, pretty);
                }
                if !v.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, e)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    e.write(out, indent + 1, pretty);
                }
                if !m.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Builder helpers for writer-side ergonomics.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr(items: Vec<Json>) -> Json {
    Json::Arr(items)
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

/// (`bool` is a keyword, hence the long name.)
pub fn boolean(v: bool) -> Json {
    Json::Bool(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected {:?} at {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at {}", other.map(|c| c as char), self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|t| t.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| "bad \\u")?;
                            let cp = u32::from_str_radix(hex, 16).map_err(|_| "bad \\u")?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        other => return Err(format!("bad escape {:?}", other)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a full UTF-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "invalid utf8")?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("bad array at {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("bad object at {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_manifest_like() {
        let src = r#"{"name": "lenet300", "density": 0.09, "layers": [
            {"name": "fc1", "shape": [784, 300], "activation": null}],
            "ok": true}"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(j.get("name").unwrap().as_str().unwrap(), "lenet300");
        assert!((j.get("density").unwrap().as_f64().unwrap() - 0.09).abs() < 1e-12);
        let layers = j.get("layers").unwrap().as_arr().unwrap();
        assert_eq!(layers[0].path("shape").unwrap().as_arr().unwrap().len(), 2);
        assert!(layers[0].get("activation").unwrap().is_null());
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn roundtrip_writer() {
        let src = r#"{"a":[1,2.5,-3],"b":"x\"y\n","c":null,"d":false}"#;
        let j = Json::parse(src).unwrap();
        let out = j.to_string_compact();
        let j2 = Json::parse(&out).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_escape() {
        let j = Json::parse(r#""éx""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "éx");
    }
}
