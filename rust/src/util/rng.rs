//! SplitMix64 — tiny, fast, deterministic PRNG (public-domain algorithm,
//! Steele et al.). Used for synthetic weight generation and property
//! tests; `rand` is not available in the offline registry and we want
//! bit-identical streams across platforms anyway.

#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n) (n > 0), via rejection-free Lemire-style
    /// multiply-shift (slight modulo bias is irrelevant for our uses but
    /// we avoid it anyway for the property tests' shrink determinism).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-12 {
                let u2 = self.next_f64();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Laplace(0, b) — heavy-tailed weight distributions.
    pub fn laplace(&mut self, b: f64) -> f64 {
        let u = self.next_f64() - 0.5;
        -b * u.signum() * (1.0 - 2.0 * u.abs()).ln()
    }

    pub fn fill_normal_f32(&mut self, out: &mut [f32], mean: f32, std: f32) {
        for v in out.iter_mut() {
            *v = mean + std * self.normal() as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_range() {
        let mut r = SplitMix64::new(1);
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            let f = r.next_f64();
            assert!((0.0..1.0).contains(&f));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = SplitMix64::new(7);
        let n = 20_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let v = r.normal();
            s += v;
            s2 += v * v;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn laplace_is_symmetric_heavy() {
        let mut r = SplitMix64::new(9);
        let n = 20_000;
        let mut s = 0.0;
        let mut abs = 0.0;
        for _ in 0..n {
            let v = r.laplace(1.0);
            s += v;
            abs += v.abs();
        }
        assert!((s / n as f64).abs() < 0.05);
        // E|Laplace(0,1)| = 1
        assert!(((abs / n as f64) - 1.0).abs() < 0.05);
    }
}
