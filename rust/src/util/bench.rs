//! Tiny benchmark harness (criterion is not in the offline registry).
//! Used by the `[[bench]] harness = false` targets: warmup + N timed
//! iterations, reporting min/median/mean.

use std::time::Instant;

#[derive(Debug, Clone, Copy)]
pub struct Stats {
    pub min_s: f64,
    pub median_s: f64,
    pub mean_s: f64,
    pub iters: usize,
}

impl Stats {
    pub fn throughput(&self, items: f64) -> f64 {
        items / self.median_s
    }
}

/// Time `f` (which must consume/produce enough to avoid DCE — return a
/// value and we black-box it) for `iters` iterations after `warmup` runs.
pub fn bench<T>(warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> Stats {
    for _ in 0..warmup {
        black_box(f());
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        black_box(f());
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    Stats {
        min_s: times[0],
        median_s: times[times.len() / 2],
        mean_s: mean,
        iters,
    }
}

/// Opaque value sink (std::hint::black_box re-export for stable use).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// One formatted result line, consistent across benches:
/// `name  median  throughput`
pub fn report_line(name: &str, stats: &Stats, items: f64, unit: &str) {
    println!(
        "{:<44} median {:>9.3} ms   {:>10.2} {unit}",
        name,
        stats.median_s * 1e3,
        stats.throughput(items) / 1e6,
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_ordering() {
        let s = bench(1, 16, || {
            let mut acc = 0u64;
            for i in 0..1000u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert!(s.min_s <= s.median_s);
        assert!(s.median_s > 0.0);
        assert_eq!(s.iters, 16);
    }
}
