//! Small self-contained utilities: deterministic RNG, a minimal JSON
//! parser/writer (serde is not available in the offline registry), a
//! property-testing mini-harness, and timing helpers.

pub mod bench;
pub mod json;
pub mod par;
pub mod ptest;
pub mod rng;
pub mod timer;

pub use rng::SplitMix64;
pub use timer::Timer;
