//! Small self-contained utilities: deterministic RNG, a minimal JSON
//! parser/writer (serde is not available in the offline registry), a
//! property-testing mini-harness, and timing helpers.

pub mod bench;
pub mod json;
pub mod par;
pub mod poll;
pub mod ptest;
pub mod rng;
pub mod timer;

pub use rng::SplitMix64;
pub use timer::Timer;

/// FNV-1a 64-bit content hash — a cheap fingerprint for byte-identity
/// checks (e.g. every (S, λ) sweep grid point vs the serial single-point
/// pipeline, without retaining one container per probe). Not
/// cryptographic.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    #[test]
    fn fnv1a_known_vectors() {
        // reference values from the FNV-1a 64-bit specification
        assert_eq!(super::fnv1a(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(super::fnv1a(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_ne!(super::fnv1a(b"ab"), super::fnv1a(b"ba"));
    }
}
