//! Scoped-thread fan-out shared by the coordinator's chunk encoder and
//! the container's chunk decoder.

/// Apply `f` to every index in `0..n` across up to `workers` scoped
/// threads (work-stealing via an atomic counter); results come back in
/// index order. `workers <= 1` (or `n <= 1`) runs inline.
pub fn map_indexed<T: Send>(
    n: usize,
    workers: usize,
    f: impl Fn(usize) -> T + Sync,
) -> Vec<T> {
    let workers = workers.clamp(1, n.max(1));
    if workers == 1 {
        return (0..n).map(f).collect();
    }
    let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let next = std::sync::atomic::AtomicUsize::new(0);
    let (tx, rx) = std::sync::mpsc::channel::<(usize, T)>();
    let f = &f;
    std::thread::scope(|scope| {
        for _ in 0..workers {
            let tx = tx.clone();
            let next = &next;
            scope.spawn(move || loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= n {
                    break;
                }
                if tx.send((i, f(i))).is_err() {
                    break;
                }
            });
        }
        drop(tx);
        for (i, out) in rx {
            slots[i] = Some(out);
        }
    });
    slots.into_iter().map(|s| s.expect("worker dropped an index")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_index_order() {
        let out = map_indexed(37, 4, |i| i * i);
        assert_eq!(out, (0..37).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn serial_and_parallel_agree() {
        let serial = map_indexed(10, 1, |i| format!("x{i}"));
        let parallel = map_indexed(10, 8, |i| format!("x{i}"));
        assert_eq!(serial, parallel);
        assert!(map_indexed(0, 4, |i| i).is_empty());
    }
}
