//! Scoped-thread fan-out shared by the coordinator's chunk encoder and
//! the container's chunk decoder, plus the persistent [`WorkerPool`]
//! that bounds the model-delivery server's connection handling.

/// Apply `f` to every index in `0..n` across up to `workers` scoped
/// threads (work-stealing via an atomic counter); results come back in
/// index order. `workers <= 1` (or `n <= 1`) runs inline.
pub fn map_indexed<T: Send>(
    n: usize,
    workers: usize,
    f: impl Fn(usize) -> T + Sync,
) -> Vec<T> {
    let workers = workers.clamp(1, n.max(1));
    if workers == 1 {
        return (0..n).map(f).collect();
    }
    let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let next = std::sync::atomic::AtomicUsize::new(0);
    let (tx, rx) = std::sync::mpsc::channel::<(usize, T)>();
    let f = &f;
    std::thread::scope(|scope| {
        for _ in 0..workers {
            let tx = tx.clone();
            let next = &next;
            scope.spawn(move || loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= n {
                    break;
                }
                if tx.send((i, f(i))).is_err() {
                    break;
                }
            });
        }
        drop(tx);
        for (i, out) in rx {
            slots[i] = Some(out);
        }
    });
    slots.into_iter().map(|s| s.expect("worker dropped an index")).collect()
}

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A persistent fixed-size thread pool (`std` only). At most `size` jobs
/// run concurrently and at most `4 × size` queue; [`WorkerPool::execute`]
/// **blocks** once the queue is full — that backpressure is what bounds
/// the serve accept loop (pending sockets stay in the kernel backlog
/// instead of accumulating fds in an unbounded queue). Never call
/// `execute` from inside a job: with the queue full it would deadlock.
/// For dependent task graphs (a job whose completion should trigger the
/// next), route completions through a channel back to a coordinator
/// thread that does the follow-up `execute` — the sweep engine's chained
/// (layer × S) dispatch in `coordinator/sweep.rs` is the reference
/// pattern, and it keeps its in-flight count under
/// [`WorkerPool::queue_capacity`] so submission never blocks at all.
/// A panicking job is caught and logged; the worker survives it.
/// Dropping the pool drains the queue: already-submitted jobs still run,
/// then workers exit.
pub struct WorkerPool {
    tx: Option<std::sync::mpsc::SyncSender<Job>>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl WorkerPool {
    pub fn new(size: usize) -> Self {
        let size = size.max(1);
        let (tx, rx) = std::sync::mpsc::sync_channel::<Job>(size * 4);
        let rx = std::sync::Arc::new(std::sync::Mutex::new(rx));
        let workers = (0..size)
            .map(|i| {
                let rx = rx.clone();
                std::thread::Builder::new()
                    .name(format!("pool-{i}"))
                    .spawn(move || loop {
                        // hold the lock only to pick up a job, not to run it
                        let job = match rx.lock() {
                            Ok(guard) => guard.recv(),
                            Err(_) => break,
                        };
                        match job {
                            Ok(job) => {
                                if std::panic::catch_unwind(std::panic::AssertUnwindSafe(job))
                                    .is_err()
                                {
                                    eprintln!("[pool] worker job panicked (recovered)");
                                }
                            }
                            Err(_) => break, // all senders gone
                        }
                    })
                    .expect("spawn pool worker")
            })
            .collect();
        Self { tx: Some(tx), workers }
    }

    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// How many jobs can queue before [`Self::execute`] blocks (the
    /// sync-channel bound; running jobs are not counted). Coordinators
    /// that chain dependent tasks cap their outstanding submissions
    /// below this so submission stays non-blocking.
    pub fn queue_capacity(&self) -> usize {
        self.workers.len() * 4
    }

    /// Queue a job; it runs as soon as a worker frees up. Blocks while
    /// the queue is at capacity (see the type docs).
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        if let Some(tx) = &self.tx {
            let _ = tx.send(Box::new(job));
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.tx.take(); // close the queue → workers exit after draining it
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_index_order() {
        let out = map_indexed(37, 4, |i| i * i);
        assert_eq!(out, (0..37).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn serial_and_parallel_agree() {
        let serial = map_indexed(10, 1, |i| format!("x{i}"));
        let parallel = map_indexed(10, 8, |i| format!("x{i}"));
        assert_eq!(serial, parallel);
        assert!(map_indexed(0, 4, |i| i).is_empty());
    }

    #[test]
    fn pool_runs_all_jobs_before_drop_returns() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        use std::sync::Arc;
        let counter = Arc::new(AtomicUsize::new(0));
        {
            let pool = WorkerPool::new(4);
            assert_eq!(pool.size(), 4);
            assert_eq!(pool.queue_capacity(), 16);
            for _ in 0..64 {
                let counter = counter.clone();
                pool.execute(move || {
                    counter.fetch_add(1, Ordering::SeqCst);
                });
            }
        } // drop waits for the queue to drain
        assert_eq!(counter.load(Ordering::SeqCst), 64);
    }

    #[test]
    fn pool_survives_panicking_job() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        use std::sync::Arc;
        let counter = Arc::new(AtomicUsize::new(0));
        {
            let pool = WorkerPool::new(1);
            pool.execute(|| panic!("boom"));
            let counter = counter.clone();
            pool.execute(move || {
                counter.fetch_add(1, Ordering::SeqCst);
            });
        }
        // the single worker recovered from the panic and ran the next job
        assert_eq!(counter.load(Ordering::SeqCst), 1);
    }
}
