//! Readiness polling over the OS event queue — epoll on Linux, kqueue on
//! macOS — plus the cross-thread [`Waker`] the event-loop server uses to
//! hear about completed offloaded work.
//!
//! The bindings are `extern "C"` declarations against symbols `std`
//! already links on these platforms (libc/libSystem), so no external
//! crate is needed and the build stays offline. Only fixed-arity
//! syscalls are declared — variadic functions like `fcntl` have a
//! different calling convention on some targets (notably Apple arm64),
//! so nonblocking mode is set through `std`'s own
//! `set_nonblocking` instead. On platforms without a supported event
//! queue [`Poller::new`] returns an error and [`supported`] is `false`;
//! callers fall back to the thread-per-connection server.

use anyhow::{Context, Result};
use std::net::UdpSocket;
use std::time::Duration;

#[cfg(unix)]
pub use std::os::fd::RawFd;
#[cfg(not(unix))]
pub type RawFd = i32;

/// Which readiness transitions to watch a descriptor for.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Interest {
    pub readable: bool,
    pub writable: bool,
}

impl Interest {
    pub const READ: Interest = Interest { readable: true, writable: false };
    pub const WRITE: Interest = Interest { readable: false, writable: true };
    pub const BOTH: Interest = Interest { readable: true, writable: true };
}

/// One readiness event out of [`Poller::wait`].
#[derive(Debug, Clone, Copy)]
pub struct Event {
    /// The token the descriptor was registered with.
    pub token: u64,
    pub readable: bool,
    pub writable: bool,
    /// Peer hung up / descriptor errored — the owner should tear the
    /// connection down after flushing what it can.
    pub hangup: bool,
}

/// True when this build has a real readiness backend (epoll/kqueue).
pub fn supported() -> bool {
    cfg!(any(target_os = "linux", target_os = "android", target_os = "macos"))
}

// ---------------------------------------------------------------------------
// Linux: epoll
// ---------------------------------------------------------------------------

#[cfg(any(target_os = "linux", target_os = "android"))]
mod sys {
    use super::{Event, Interest, RawFd};
    use anyhow::{bail, Result};
    use std::time::Duration;

    const EPOLL_CLOEXEC: i32 = 0o2000000;
    const EPOLL_CTL_ADD: i32 = 1;
    const EPOLL_CTL_DEL: i32 = 2;
    const EPOLL_CTL_MOD: i32 = 3;
    const EPOLLIN: u32 = 0x001;
    const EPOLLOUT: u32 = 0x004;
    const EPOLLERR: u32 = 0x008;
    const EPOLLHUP: u32 = 0x010;
    const EPOLLRDHUP: u32 = 0x2000;

    /// Kernel ABI struct. Packed on x86-64 (the kernel declares it
    /// `__attribute__((packed))` there and only there).
    #[repr(C)]
    #[cfg_attr(target_arch = "x86_64", repr(packed))]
    #[derive(Clone, Copy)]
    struct EpollEvent {
        events: u32,
        data: u64,
    }

    extern "C" {
        fn epoll_create1(flags: i32) -> i32;
        fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut EpollEvent) -> i32;
        fn epoll_wait(epfd: i32, events: *mut EpollEvent, maxevents: i32, timeout: i32) -> i32;
        fn close(fd: i32) -> i32;
    }

    pub struct Poller {
        epfd: RawFd,
    }

    // The epoll fd is just a kernel handle; all methods are &self-safe
    // (epoll_ctl/epoll_wait are thread-safe per POSIX).
    unsafe impl Send for Poller {}
    unsafe impl Sync for Poller {}

    fn mask(interest: Interest) -> u32 {
        let mut m = EPOLLRDHUP;
        if interest.readable {
            m |= EPOLLIN;
        }
        if interest.writable {
            m |= EPOLLOUT;
        }
        m
    }

    impl Poller {
        pub fn new() -> Result<Self> {
            let epfd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
            if epfd < 0 {
                bail!("epoll_create1: {}", std::io::Error::last_os_error());
            }
            Ok(Self { epfd })
        }

        fn ctl(&self, op: i32, fd: RawFd, token: u64, interest: Interest) -> Result<()> {
            let mut ev = EpollEvent { events: mask(interest), data: token };
            let rc = unsafe { epoll_ctl(self.epfd, op, fd, &mut ev) };
            if rc < 0 {
                bail!("epoll_ctl(op={op}, fd={fd}): {}", std::io::Error::last_os_error());
            }
            Ok(())
        }

        pub fn register(&self, fd: RawFd, token: u64, interest: Interest) -> Result<()> {
            self.ctl(EPOLL_CTL_ADD, fd, token, interest)
        }

        pub fn modify(&self, fd: RawFd, token: u64, interest: Interest) -> Result<()> {
            self.ctl(EPOLL_CTL_MOD, fd, token, interest)
        }

        pub fn deregister(&self, fd: RawFd) -> Result<()> {
            let mut ev = EpollEvent { events: 0, data: 0 };
            let rc = unsafe { epoll_ctl(self.epfd, EPOLL_CTL_DEL, fd, &mut ev) };
            if rc < 0 {
                bail!("epoll_ctl(DEL, fd={fd}): {}", std::io::Error::last_os_error());
            }
            Ok(())
        }

        /// Blocking wait (level-triggered); `timeout` of `None` blocks
        /// indefinitely. Appends to `out`.
        pub fn wait(&self, out: &mut Vec<Event>, timeout: Option<Duration>) -> Result<()> {
            let mut buf = [EpollEvent { events: 0, data: 0 }; 256];
            let ms: i32 = match timeout {
                None => -1,
                Some(t) => t.as_millis().min(i32::MAX as u128) as i32,
            };
            let n = loop {
                let rc = unsafe {
                    epoll_wait(self.epfd, buf.as_mut_ptr(), buf.len() as i32, ms)
                };
                if rc >= 0 {
                    break rc as usize;
                }
                let err = std::io::Error::last_os_error();
                if err.kind() == std::io::ErrorKind::Interrupted {
                    continue;
                }
                bail!("epoll_wait: {err}");
            };
            for e in &buf[..n] {
                // copy out of the (possibly packed) struct before use
                let events = e.events;
                let data = e.data;
                out.push(Event {
                    token: data,
                    readable: events & (EPOLLIN | EPOLLRDHUP) != 0,
                    writable: events & EPOLLOUT != 0,
                    hangup: events & (EPOLLERR | EPOLLHUP) != 0,
                });
            }
            Ok(())
        }
    }

    impl Drop for Poller {
        fn drop(&mut self) {
            unsafe {
                close(self.epfd);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// macOS: kqueue
// ---------------------------------------------------------------------------

#[cfg(target_os = "macos")]
mod sys {
    use super::{Event, Interest, RawFd};
    use anyhow::{bail, Result};
    use std::time::Duration;

    const EVFILT_READ: i16 = -1;
    const EVFILT_WRITE: i16 = -2;
    const EV_ADD: u16 = 0x0001;
    const EV_DELETE: u16 = 0x0002;
    const EV_ENABLE: u16 = 0x0004;
    const EV_DISABLE: u16 = 0x0008;
    const EV_EOF: u16 = 0x8000;
    const EV_ERROR: u16 = 0x4000;

    #[repr(C)]
    struct KEvent {
        ident: usize,
        filter: i16,
        flags: u16,
        fflags: u32,
        data: isize,
        udata: usize,
    }

    #[repr(C)]
    struct Timespec {
        tv_sec: i64,
        tv_nsec: i64,
    }

    extern "C" {
        fn kqueue() -> i32;
        fn kevent(
            kq: i32,
            changelist: *const KEvent,
            nchanges: i32,
            eventlist: *mut KEvent,
            nevents: i32,
            timeout: *const Timespec,
        ) -> i32;
        fn close(fd: i32) -> i32;
    }

    pub struct Poller {
        kq: RawFd,
    }

    unsafe impl Send for Poller {}
    unsafe impl Sync for Poller {}

    impl Poller {
        pub fn new() -> Result<Self> {
            let kq = unsafe { kqueue() };
            if kq < 0 {
                bail!("kqueue: {}", std::io::Error::last_os_error());
            }
            Ok(Self { kq })
        }

        fn submit(&self, changes: &[KEvent]) -> Result<()> {
            let rc = unsafe {
                kevent(
                    self.kq,
                    changes.as_ptr(),
                    changes.len() as i32,
                    std::ptr::null_mut(),
                    0,
                    std::ptr::null(),
                )
            };
            if rc < 0 {
                bail!("kevent(changes): {}", std::io::Error::last_os_error());
            }
            Ok(())
        }

        fn interest_changes(fd: RawFd, token: u64, interest: Interest) -> [KEvent; 2] {
            let flag = |on: bool| EV_ADD | if on { EV_ENABLE } else { EV_DISABLE };
            [
                KEvent {
                    ident: fd as usize,
                    filter: EVFILT_READ,
                    flags: flag(interest.readable),
                    fflags: 0,
                    data: 0,
                    udata: token as usize,
                },
                KEvent {
                    ident: fd as usize,
                    filter: EVFILT_WRITE,
                    flags: flag(interest.writable),
                    fflags: 0,
                    data: 0,
                    udata: token as usize,
                },
            ]
        }

        pub fn register(&self, fd: RawFd, token: u64, interest: Interest) -> Result<()> {
            self.submit(&Self::interest_changes(fd, token, interest))
        }

        pub fn modify(&self, fd: RawFd, token: u64, interest: Interest) -> Result<()> {
            self.submit(&Self::interest_changes(fd, token, interest))
        }

        pub fn deregister(&self, fd: RawFd) -> Result<()> {
            // best effort: one or both filters may not be registered
            for filter in [EVFILT_READ, EVFILT_WRITE] {
                let ch = KEvent {
                    ident: fd as usize,
                    filter,
                    flags: EV_DELETE,
                    fflags: 0,
                    data: 0,
                    udata: 0,
                };
                let _ = self.submit(std::slice::from_ref(&ch));
            }
            Ok(())
        }

        pub fn wait(&self, out: &mut Vec<Event>, timeout: Option<Duration>) -> Result<()> {
            let mut buf: Vec<KEvent> = Vec::with_capacity(256);
            let ts;
            let ts_ptr = match timeout {
                None => std::ptr::null(),
                Some(t) => {
                    ts = Timespec {
                        tv_sec: t.as_secs() as i64,
                        tv_nsec: t.subsec_nanos() as i64,
                    };
                    &ts as *const Timespec
                }
            };
            let n = loop {
                let rc = unsafe {
                    kevent(self.kq, std::ptr::null(), 0, buf.as_mut_ptr(), 256, ts_ptr)
                };
                if rc >= 0 {
                    break rc as usize;
                }
                let err = std::io::Error::last_os_error();
                if err.kind() == std::io::ErrorKind::Interrupted {
                    continue;
                }
                bail!("kevent(wait): {err}");
            };
            unsafe { buf.set_len(n) };
            for e in &buf {
                out.push(Event {
                    token: e.udata as u64,
                    readable: e.filter == EVFILT_READ,
                    writable: e.filter == EVFILT_WRITE,
                    hangup: e.flags & (EV_EOF | EV_ERROR) != 0,
                });
            }
            Ok(())
        }
    }

    impl Drop for Poller {
        fn drop(&mut self) {
            unsafe {
                close(self.kq);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Everything else: stub that reports itself unsupported
// ---------------------------------------------------------------------------

#[cfg(not(any(target_os = "linux", target_os = "android", target_os = "macos")))]
mod sys {
    use super::{Event, Interest, RawFd};
    use anyhow::{bail, Result};
    use std::time::Duration;

    pub struct Poller;

    impl Poller {
        pub fn new() -> Result<Self> {
            bail!("readiness polling is not supported on this platform — use --threaded")
        }
        pub fn register(&self, _fd: RawFd, _token: u64, _i: Interest) -> Result<()> {
            unreachable!("Poller::new never succeeds here")
        }
        pub fn modify(&self, _fd: RawFd, _token: u64, _i: Interest) -> Result<()> {
            unreachable!("Poller::new never succeeds here")
        }
        pub fn deregister(&self, _fd: RawFd) -> Result<()> {
            unreachable!("Poller::new never succeeds here")
        }
        pub fn wait(&self, _out: &mut Vec<Event>, _t: Option<Duration>) -> Result<()> {
            unreachable!("Poller::new never succeeds here")
        }
    }
}

pub use sys::Poller;

// ---------------------------------------------------------------------------
// Waker
// ---------------------------------------------------------------------------

/// Cross-thread wakeup for a [`Poller`] loop: worker threads call
/// [`Waker::wake`] after posting a completion, and the loop — registered
/// on [`Waker::fd`] — gets a readable event even if it was parked in
/// `wait`. Implemented as a self-connected nonblocking UDP socket so it
/// works identically on every Unix without extra syscall bindings; the
/// datagrams never leave the loopback interface.
pub struct Waker {
    sock: UdpSocket,
}

impl Waker {
    pub fn new() -> Result<Self> {
        let sock = UdpSocket::bind("127.0.0.1:0").context("binding waker socket")?;
        let addr = sock.local_addr().context("waker local addr")?;
        sock.connect(addr).context("self-connecting waker")?;
        sock.set_nonblocking(true).context("waker nonblocking")?;
        Ok(Self { sock })
    }

    #[cfg(unix)]
    pub fn fd(&self) -> RawFd {
        use std::os::fd::AsRawFd;
        self.sock.as_raw_fd()
    }

    #[cfg(not(unix))]
    pub fn fd(&self) -> RawFd {
        -1
    }

    /// Nudge the loop. Nonblocking and infallible by design: if the
    /// socket buffer is already full, a wakeup is already pending and
    /// dropping this one loses nothing.
    pub fn wake(&self) {
        let _ = self.sock.send(&[1u8]);
    }

    /// Swallow all pending wakeups (the loop calls this once per
    /// readable event on the waker fd).
    pub fn drain(&self) {
        let mut buf = [0u8; 64];
        while let Ok(n) = self.sock.recv(&mut buf) {
            if n == 0 {
                break;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read, Write};
    use std::time::Duration;

    #[test]
    fn waker_roundtrip() {
        let w = Waker::new().unwrap();
        w.wake();
        w.wake();
        w.drain(); // must not block or panic
    }

    #[cfg(any(target_os = "linux", target_os = "android", target_os = "macos"))]
    #[test]
    fn poller_sees_waker_readability() {
        let poller = Poller::new().unwrap();
        let w = Waker::new().unwrap();
        poller.register(w.fd(), 7, Interest::READ).unwrap();

        // nothing pending: a short wait returns no events
        let mut events = Vec::new();
        poller.wait(&mut events, Some(Duration::from_millis(10))).unwrap();
        assert!(events.iter().all(|e| e.token != 7 || !e.readable));

        w.wake();
        let mut events = Vec::new();
        poller.wait(&mut events, Some(Duration::from_millis(1000))).unwrap();
        assert!(events.iter().any(|e| e.token == 7 && e.readable));
        w.drain();

        // level-triggered: after draining, readability clears
        let mut events = Vec::new();
        poller.wait(&mut events, Some(Duration::from_millis(10))).unwrap();
        assert!(events.iter().all(|e| e.token != 7 || !e.readable));
        poller.deregister(w.fd()).unwrap();
    }

    #[cfg(any(target_os = "linux", target_os = "android", target_os = "macos"))]
    #[test]
    fn poller_tracks_tcp_read_write_interest() {
        use std::net::{TcpListener, TcpStream};
        use std::os::fd::AsRawFd;

        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        server.set_nonblocking(true).unwrap();

        let poller = Poller::new().unwrap();
        poller.register(server.as_raw_fd(), 42, Interest::BOTH).unwrap();

        // a fresh socket is writable but not readable
        let mut events = Vec::new();
        poller.wait(&mut events, Some(Duration::from_millis(1000))).unwrap();
        let ev = events.iter().find(|e| e.token == 42).expect("event for socket");
        assert!(ev.writable && !ev.readable);

        // after the peer writes, readable shows up
        client.write_all(b"ping").unwrap();
        client.flush().unwrap();
        let mut events = Vec::new();
        for _ in 0..100 {
            events.clear();
            poller.wait(&mut events, Some(Duration::from_millis(100))).unwrap();
            if events.iter().any(|e| e.token == 42 && e.readable) {
                break;
            }
        }
        assert!(events.iter().any(|e| e.token == 42 && e.readable));
        let mut buf = [0u8; 8];
        let mut srv = &server;
        let n = srv.read(&mut buf).unwrap();
        assert_eq!(&buf[..n], b"ping");

        // peer hangs up → hangup (or at least readable EOF) is reported
        drop(client);
        let mut saw_close = false;
        for _ in 0..100 {
            let mut events = Vec::new();
            poller.wait(&mut events, Some(Duration::from_millis(100))).unwrap();
            if events.iter().any(|e| e.token == 42 && (e.hangup || e.readable)) {
                saw_close = true;
                break;
            }
        }
        assert!(saw_close);
        poller.deregister(server.as_raw_fd()).unwrap();
    }
}
