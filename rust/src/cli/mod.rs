//! Hand-rolled CLI (clap is not in the offline registry).
//!
//! ```text
//! deepcabac table1 [--large] [--scale N] [--no-eval] [--sweep N] [--workers N]
//! deepcabac compress   --model NAME --out FILE [--s N | --sweep N] [--lambda-scale X]
//! deepcabac decompress --in FILE --out-dir DIR
//! deepcabac eval       --model NAME [--compressed FILE]
//! deepcabac anatomy    [--levels "1,0,-3,..."]
//! deepcabac sweep      (--model NAME | --arch vgg16) [--points N] [--workers N]
//!                      [--lambdas A,B,... | --lambda-sweep N]
//!                      [--sweep-exhaustive] [--no-abandon | --abandon-argmin]
//!                      [--warm-start | --cold] [--compare-serial]
//!                      [--json FILE] [--csv FILE] [--out FILE] [--select-lambda X]
//!                      [--progressive [--tiers K] [--out-tiers DIR]]
//! deepcabac materialize --in PROG.dcbc [--tier T] --out FILE
//! deepcabac synth      --arch vgg16 [--scale N] [--s N]
//! deepcabac delta      encode|apply|bench (see USAGE)
//! ```
//!
//! `delta` is the one subcommand with an action word; `main` folds
//! `delta encode` into the single command string `delta-encode` before
//! parsing, so this parser still never sees positional arguments.

use std::collections::HashMap;

#[derive(Debug)]
pub struct Args {
    pub cmd: String,
    flags: HashMap<String, String>,
    pub switches: Vec<String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Self, String> {
        if argv.is_empty() {
            return Err("no subcommand".into());
        }
        let cmd = argv[0].clone();
        let mut flags = HashMap::new();
        let mut switches = Vec::new();
        let mut i = 1;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    flags.insert(name.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    switches.push(name.to_string());
                    i += 1;
                }
            } else {
                return Err(format!("unexpected positional argument {a:?}"));
            }
        }
        Ok(Self { cmd, flags, switches })
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name} expects an integer")),
        }
    }

    /// Like [`Self::get_usize`] but rejects 0 with a usage error — the
    /// uniform validator for count-like flags (`--workers`, `--clients`,
    /// `--requests`, `--chunks`, …) where zero is always a mistake.
    pub fn get_count(&self, name: &str, default: usize) -> Result<usize, String> {
        let v = self.get_usize(name, default)?;
        if v == 0 {
            return Err(format!("--{name} must be >= 1"));
        }
        Ok(v)
    }

    pub fn get_f32(&self, name: &str, default: f32) -> Result<f32, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name} expects a float")),
        }
    }

    /// Comma-separated float-list flag (e.g. `--lambdas 0.01,0.05,0.2`).
    /// `Ok(None)` when absent; the uniform validator for grid-like
    /// flags: empty lists, unparsable tokens, and non-finite/negative
    /// values are all usage errors (matching [`Self::get_count`]'s
    /// reject-zero hardening), never downstream panics.
    pub fn get_f32s(&self, name: &str) -> Result<Option<Vec<f32>>, String> {
        let Some(raw) = self.get(name) else {
            return Ok(None);
        };
        let mut out = Vec::new();
        for tok in raw.split(',').map(str::trim).filter(|t| !t.is_empty()) {
            let v: f32 = tok
                .parse()
                .map_err(|_| format!("--{name}: {tok:?} is not a float"))?;
            if !v.is_finite() || v < 0.0 {
                return Err(format!(
                    "--{name} values must be finite and >= 0 (got {tok})"
                ));
            }
            // "-0.0" passes the >= 0 check; normalize so its bit pattern
            // can't split a λ-column downstream
            out.push(if v == 0.0 { 0.0 } else { v });
        }
        if out.is_empty() {
            return Err(format!("--{name} needs at least one value (empty list)"));
        }
        Ok(Some(out))
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }
}

pub const USAGE: &str = "\
deepcabac — context-adaptive binary arithmetic coding for DNN compression
(reproduction of Wiedemann et al., ICML 2019)

USAGE:
  deepcabac table1 [--large] [--scale N] [--no-eval] [--sweep N] [--workers N]
      Regenerate the paper's Table 1 (small trained models; --large adds
      the synthetic ImageNet-scale rows at 1/N channel scale).
  deepcabac compress --model NAME --out FILE [--s N | --sweep N]
                     [--lambda-scale X] [--workers N] [--chunks N]
      Compress a trained model from artifacts/ into a .dcbc container.
      --chunks N > 1 splits every tensor into N independently coded
      streams (container v2) so one giant layer encodes and decodes in
      parallel; N = 1 (default) keeps the original v1 bitstream.
  deepcabac decompress --in FILE --out-dir DIR
      Reconstruct weight tensors from a container into .npy files.
  deepcabac eval --model NAME [--compressed FILE]
      Accuracy/PSNR via the PJRT runtime (original or compressed weights).
  deepcabac anatomy [--levels L1,L2,...]
      Figure 1: per-bin trace of the binarization of a level sequence.
  deepcabac sweep (--model NAME | --arch vgg16|resnet50|mobilenet [--scale N]
                  [--seed N]) [--points N] [--workers N] [--lambda-scale X]
                  [--lambdas A,B,... | --lambda-sweep N] [--eval]
                  [--sweep-exhaustive] [--no-abandon | --abandon-argmin]
                  [--warm-start | --cold] [--compare-serial]
                  [--json FILE] [--csv FILE] [--out FILE] [--select-lambda X]
                  [--delta-from BASE.dcbc] [--out-delta FILE]
      The 2-D (S × λ) rate-distortion surface sweep on the parallel
      incremental engine: coarse-to-fine refinement over S ∈ {0..256}
      per λ-column ((layer × S × λ) probe tasks fanned over --workers
      threads, per-layer statistics shared across the whole surface).
      --lambdas gives explicit λ (lambda_scale) columns; --lambda-sweep
      N uses λ=0 plus N-1 log-spaced columns over [0.01, 1.0] (N=1 is
      just the 0.05 default; the two flags are mutually exclusive);
      neither = the single --lambda-scale column (the paper's pure S
      sweep).
      Refinement probes warm-start from their λ-column incumbent's
      quantized levels (byte-identical containers either way — the seed
      only speeds up the per-weight argmin certificate; --cold disables
      seeding for identity checks, --warm-start is the default).
      Early abandonment is frontier-preserving by default: a probe is
      cut only when it is over its λ-column's byte budget AND its
      running (bytes, distortion) lower bound is strictly
      Pareto-dominated by a completed point, so the reported frontier,
      every per-column argmin, and the overall winner are identical to
      a --no-abandon run. --abandon-argmin switches to the faster
      byte-budget-only mode (argmins still exact; losing low-distortion
      probes may vanish from the frontier); --no-abandon completes
      every probe (full per-point stats).
      --eval re-evaluates every λ-column's
      argmin container through PJRT (the accuracy-vs-λ trace the old
      serial rd_sweep example printed; needs a trained --model).
      --sweep-exhaustive probes all 257 S per column;
      --compare-serial recompresses every completed grid point serially
      and verifies byte-identity against the engine's per-point
      fingerprints. Writes the Pareto frontier + per-column argmins +
      warm-start hit rates + abandonment reasons to --json (default
      BENCH_sweep.json), per-point CSV to --csv, and the best container
      to --out (--select-lambda X writes λ-column X's argmin instead of
      the overall smallest).
      --delta-from BASE.dcbc switches the selection objective to the
      size of each grid point's v3 delta segment against that base
      (the incremental-update question: which (S, λ) is cheapest to
      *ship to clients that already hold BASE*). Every completed point
      is delta-encoded against a parent context hoisted once; the
      winner's delta segment is reported in the JSON and written to
      --out-delta. Abandonment is forced off in this mode (full-byte
      budgets don't order points by delta bytes); warm-start still
      applies.
      --progressive picks up to --tiers K (default 3) evenly spaced
      points along the swept Pareto frontier (coarsest first, finest
      last), recompresses each, and chain-encodes them into ONE .dcbc
      v4 progressive container: a v2-shaped base tier plus CABAC-coded
      level residuals per refinement tier, cut so that every tier
      boundary is a decodable container prefix. --out writes the v4
      container, --out-tiers DIR writes each tier's standalone
      container (tier_0.dcbc …; `materialize` reproduces them
      byte-for-byte from the v4 file), and a per-tier size/overhead
      report goes to BENCH_progressive.json. Incompatible with
      --delta-from and --select-lambda.
  deepcabac materialize --in PROG.dcbc [--tier T] --out FILE [--workers N]
      Extract tier T (default: the finest) of a progressive v4 container
      as a standalone v1/v2 container, byte-identical to the container
      that tier was chained from.
  deepcabac synth --arch vgg16|resnet50|mobilenet [--scale N] [--s N]
                  [--seed N] [--out FILE] [--perturb-density X]
                  [--perturb-scale Y] [--perturb-seed N] [--workers N]
      Generate + compress a synthetic ImageNet-scale model (--out writes
      the .dcbc container, e.g. to seed a serve directory).
      --perturb-density X nudges fraction X of the weights with
      deterministic Gaussian noise (σ = --perturb-scale, default 0.05,
      stream seeded by --perturb-seed) before compressing: two runs that
      differ only in --perturb-density yield a (parent, target)
      container pair for `deepcabac delta` (use X = 0 for the base so
      both go through the identical compression path).
  deepcabac delta encode --parent BASE.dcbc --target NEW.dcbc --out D.dcbc
                         [--workers N]
      Diff two full containers of the same architecture into a .dcbc v3
      delta segment: per layer, the residual between the target's
      quantization levels and the parent's reconstruction requantized on
      the target grid, CABAC-coded with the target's codec config.
      Byte-identical layers become skip records.
  deepcabac delta apply --parent BASE.dcbc --delta D.dcbc --out OUT.dcbc
                        [--workers N]
      Reapply a delta segment onto its base container. The output is
      byte-for-byte identical to the NEW.dcbc the delta was encoded
      from; a wrong base is rejected by parent-fingerprint check.
  deepcabac delta bench --parent BASE.dcbc --target NEW.dcbc [--iters N]
                        [--workers N] [--json FILE]
      Verify the apply round trip is byte-identical, then report delta
      vs full container bytes and apply latency (p50/p99 over --iters
      runs, default 32) to --json (default BENCH_delta.json).
  deepcabac serve --dir DIR [--addr HOST:PORT] [--cache-mb N] [--workers N]
                  [--read-timeout MS] [--write-timeout MS]
                  [--event-loop | --threaded] [--max-connections N]
      Serve every .dcbc container in DIR over HTTP: GET /models,
      /models/{m}/manifest, /models/{m}/layers/{l} (compressed bytes,
      Range supported; zero-copy from the mmap'd container),
      /models/{m}/layers/{l}/weights (server-side decode through an LRU
      cache of --cache-mb, keyed per (model, layer, tier)), /stats,
      /healthz. --addr defaults to 127.0.0.1:8080; port 0 picks an
      ephemeral port (printed on startup). Two transports serve
      byte-identical responses: --event-loop (default where supported)
      is an epoll/kqueue readiness loop with HTTP/1.1 keep-alive and
      bounded pipelining that holds thousands of mostly-idle
      connections on one thread, decode work offloaded to --workers;
      --threaded is the thread-per-connection accept loop (one worker
      per in-flight connection). Per-connection deadlines default to
      10000 ms reads / 30000 ms writes (must be >= 1): slow or stalled
      peers get 408 / a close instead of a wedged slot, counted in
      /stats (the event loop enforces the same deadlines from its poll
      timer wheel). --max-connections N sheds connections beyond N with
      503 + a `shed` counter in /stats.
  deepcabac fetch --url http://HOST:PORT/models/NAME [--layer L]
                  [--from BASE.dcbc] [--tier T [--out FILE] | --upgrade FILE]
                  [--out-dir DIR] [--workers N]
      Fetch a model from a serve endpoint. Without --layer the whole
      container is streamed through the incremental decoder (layers
      materialize while bytes arrive); --layer L (index or name) fetches
      one layer's decoded weights via random access. --from BASE.dcbc
      fetches only a delta against the local base container
      (GET .../delta?from=<fingerprint>) and applies it in place as the
      bytes arrive — reconstructed weights are identical to a full
      fetch; HTTP 409 means the server knows the base but has no delta
      from it (fetch the full container). --tier T fetches only the
      byte prefix of a progressive (v4) container up to tier T
      (GET ...?tier=T) and reconstructs the weights at that quality;
      --out saves the prefix, which is itself a valid container.
      --upgrade FILE extends a saved prefix to the server's full
      container with one Range request for the missing tail (nothing
      already held is re-downloaded). --out-dir writes {layer}.w.npy
      files.
  deepcabac loadgen --url http://HOST:PORT [--clients N] [--requests M]
                    [--hostile H] [--rate RPS] [--connections-sweep LIST]
                    [--sweep-requests K] [--out FILE]
      Load-generate against a serve endpoint (mixed compressed-bytes and
      decoded-weights GETs) and report p50/p99/p999 latency +
      throughput; failures are classified (connect-refused / timeout /
      reset / malformed-response / http-error / shed) in the report.
      Default is a closed loop (next request fires when the previous
      completes); --rate RPS switches to an open loop with Poisson
      arrivals at RPS aggregate, latency measured from each scheduled
      arrival so server slowdowns surface as queueing delay. --hostile H
      adds H fault-injecting threads (byte-dribble, slowloris,
      mid-request disconnect, stalled readers) whose outcomes are
      reported separately and never count as load failures.
      --connections-sweep 1,64,1k,10k appends a connection-scaling
      block: per count N, establish N concurrent keep-alive sockets and
      drive --sweep-requests (default 3) requests each, reporting
      established / reused / reconnects / shed and per-point
      percentiles. --out writes BENCH_serve.json-style machine-readable
      results.
  deepcabac fuzz [--target container|stream|http|range|encoder|delta_apply|all]
                 [--cases N] [--seed N] [--corpus DIR] [--artifacts DIR]
                 [--evolve [--max-time S] [--json FILE]]
      Structure-aware fuzzing of the container / stream / HTTP / Range
      parsers (v1/v2 containers and v3 delta segments) plus the encoder
      target, which decodes each input into a hostile model pair
      (denormals, signed zeros, NaN/Inf, zero-dim and huge tensors) and
      pushes it through the pipeline and the delta encoder, and the
      delta_apply target, which frames a (parent, delta) pair whose
      parent was mutated AFTER the delta fingerprinted it — apply must
      reject with a structured error or reproduce the target
      byte-exactly, never panic or overallocate. Replays the checked-in
      crasher corpus (--corpus, default fuzz_corpus/), then runs --cases
      generate-and-mutate inputs per target under the never-panic /
      alloc-budget / time-budget / roundtrip-idempotence invariants.
      Minimized reproducers go to --artifacts; exits nonzero on any
      violation. Fixed --seed makes runs bit-reproducible (the CI
      fuzz-smoke job).
      --evolve switches to the coverage-guided loop (build with
      --features fuzz-cov so the edge-counter probes record): the corpus
      seeds a pool scheduled by edge rarity, mutants reaching new edges
      are promoted (written to --artifacts as promoted_*.bin) and
      periodically re-minimized, and an edges-over-execs curve plus
      per-target unique-edge counts against the same-budget fixed-seed
      batch go to --json (default BENCH_fuzz.json). --max-time S caps
      each target's loop at S seconds (0 = run all --cases); a run with
      a fixed --seed and an uncut case budget is byte-reproducible.
";

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_flags_and_switches() {
        let a = Args::parse(&sv(&["table1", "--scale", "8", "--large", "--no-eval"]))
            .unwrap();
        assert_eq!(a.cmd, "table1");
        assert_eq!(a.get_usize("scale", 1).unwrap(), 8);
        assert!(a.has("large"));
        assert!(a.has("no-eval"));
        assert!(!a.has("quick"));
    }

    #[test]
    fn rejects_positional() {
        assert!(Args::parse(&sv(&["compress", "stray"])).is_err());
    }

    #[test]
    fn typed_accessor_errors() {
        let a = Args::parse(&sv(&["x", "--n", "abc"])).unwrap();
        assert!(a.get_usize("n", 0).is_err());
    }

    #[test]
    fn count_flags_reject_zero_uniformly() {
        // --workers 0 and --clients 0 must fail as usage errors, not leak
        // into downstream code
        let a = Args::parse(&sv(&["serve", "--workers", "0"])).unwrap();
        assert!(a.get_count("workers", 4).unwrap_err().contains("must be >= 1"));
        let a = Args::parse(&sv(&["loadgen", "--clients", "0"])).unwrap();
        assert!(a.get_count("clients", 8).unwrap_err().contains("must be >= 1"));
        // defaults and positive values pass through
        let a = Args::parse(&sv(&["serve"])).unwrap();
        assert_eq!(a.get_count("workers", 4).unwrap(), 4);
        let a = Args::parse(&sv(&["serve", "--workers", "16"])).unwrap();
        assert_eq!(a.get_count("workers", 4).unwrap(), 16);
        // non-integers still error through the same path
        let a = Args::parse(&sv(&["serve", "--workers", "many"])).unwrap();
        assert!(a.get_count("workers", 4).is_err());
    }

    #[test]
    fn parses_sweep_flags() {
        let a = Args::parse(&sv(&[
            "sweep", "--arch", "mobilenet", "--scale", "32", "--points", "9",
            "--workers", "4", "--sweep-exhaustive", "--no-abandon", "--cold",
            "--compare-serial", "--json", "B.json", "--out", "best.dcbc",
        ]))
        .unwrap();
        assert_eq!(a.cmd, "sweep");
        assert_eq!(a.get("arch"), Some("mobilenet"));
        assert_eq!(a.get_count("points", 17).unwrap(), 9);
        assert_eq!(a.get_count("workers", 1).unwrap(), 4);
        assert!(a.has("sweep-exhaustive"));
        assert!(a.has("no-abandon"));
        assert!(a.has("cold"));
        assert!(!a.has("warm-start") && !a.has("abandon-argmin"));
        assert!(a.has("compare-serial"));
        // the warm-start / abandon-mode switches parse as plain switches
        let a = Args::parse(&sv(&["sweep", "--abandon-argmin", "--warm-start"])).unwrap();
        assert!(a.has("abandon-argmin") && a.has("warm-start"));
        assert_eq!(a.get_or("json", "BENCH_sweep.json"), "B.json");
        assert_eq!(a.get("out"), Some("best.dcbc"));
        // --points 0 / --sweep 0 are usage errors, not downstream panics
        let a = Args::parse(&sv(&["sweep", "--points", "0"])).unwrap();
        assert!(a.get_count("points", 17).is_err());
        let a = Args::parse(&sv(&["table1", "--sweep", "0"])).unwrap();
        assert!(a.get_count("sweep", 17).is_err());
    }

    #[test]
    fn parses_lambda_flags_and_rejects_bad_grids() {
        let a = Args::parse(&sv(&["sweep", "--lambdas", "0.01,0.05,0.2"])).unwrap();
        assert_eq!(a.get_f32s("lambdas").unwrap(), Some(vec![0.01, 0.05, 0.2]));
        // absent flag is None, not an error
        assert_eq!(a.get_f32s("absent").unwrap(), None);
        // whitespace and trailing commas are tolerated
        let a = Args::parse(&sv(&["sweep", "--lambdas", " 0.1 ,0.2, "])).unwrap();
        assert_eq!(a.get_f32s("lambdas").unwrap(), Some(vec![0.1, 0.2]));
        // an empty λ grid is a usage error (PR 3's empty-S-grid
        // hardening, extended to the λ dimension), not a panic
        let a = Args::parse(&sv(&["sweep", "--lambdas", ","])).unwrap();
        assert!(a.get_f32s("lambdas").unwrap_err().contains("at least one"));
        let a = Args::parse(&sv(&["sweep", "--lambdas", "0.1,abc"])).unwrap();
        assert!(a.get_f32s("lambdas").unwrap_err().contains("not a float"));
        let a = Args::parse(&sv(&["sweep", "--lambdas", "0.1,-0.2"])).unwrap();
        assert!(a.get_f32s("lambdas").unwrap_err().contains(">= 0"));
        let a = Args::parse(&sv(&["sweep", "--lambdas", "nan"])).unwrap();
        assert!(a.get_f32s("lambdas").is_err());
        // "-0.0" is accepted (>= 0) but normalized to +0.0 so it can't
        // split a λ-column
        let a = Args::parse(&sv(&["sweep", "--lambdas", "-0.0"])).unwrap();
        assert_eq!(
            a.get_f32s("lambdas").unwrap().unwrap()[0].to_bits(),
            0.0f32.to_bits()
        );
        // --lambda-sweep 0 rejected through the uniform count validator
        let a = Args::parse(&sv(&["sweep", "--lambda-sweep", "0"])).unwrap();
        assert!(a.get_count("lambda-sweep", 5).is_err());
        let a = Args::parse(&sv(&["sweep", "--lambda-sweep", "3"])).unwrap();
        assert_eq!(a.get_count("lambda-sweep", 5).unwrap(), 3);
        // frontier output selection parses as a plain flag value
        let a =
            Args::parse(&sv(&["sweep", "--select-lambda", "0.2", "--out", "b.dcbc"]))
                .unwrap();
        assert_eq!(a.get("select-lambda"), Some("0.2"));
    }

    #[test]
    fn parses_progressive_flags() {
        // sweep --progressive with its tier knobs
        let a = Args::parse(&sv(&[
            "sweep", "--arch", "vgg16", "--progressive", "--tiers", "4",
            "--out", "prog.dcbc", "--out-tiers", "tiers/",
        ]))
        .unwrap();
        assert!(a.has("progressive"));
        assert_eq!(a.get_count("tiers", 3).unwrap(), 4);
        assert_eq!(a.get("out-tiers"), Some("tiers/"));
        // --tiers 0 rejected through the uniform count validator
        let a = Args::parse(&sv(&["sweep", "--progressive", "--tiers", "0"])).unwrap();
        assert!(a.get_count("tiers", 3).is_err());
        // materialize + fetch tier flags parse as plain value flags
        let a = Args::parse(&sv(&[
            "materialize", "--in", "p.dcbc", "--tier", "1", "--out", "t1.dcbc",
        ]))
        .unwrap();
        assert_eq!(a.cmd, "materialize");
        assert_eq!(a.get("tier"), Some("1"));
        let a = Args::parse(&sv(&[
            "fetch", "--url", "http://h/models/m", "--tier", "0", "--out", "base.dcbc",
        ]))
        .unwrap();
        assert_eq!(a.get("tier"), Some("0"));
        let a = Args::parse(&sv(&[
            "fetch", "--url", "http://h/models/m", "--upgrade", "base.dcbc",
        ]))
        .unwrap();
        assert_eq!(a.get("upgrade"), Some("base.dcbc"));
    }

    #[test]
    fn parses_serve_flags() {
        let a = Args::parse(&sv(&[
            "serve", "--dir", "models/", "--addr", "127.0.0.1:0", "--cache-mb", "128",
            "--workers", "8", "--read-timeout", "300", "--write-timeout", "500",
        ]))
        .unwrap();
        assert_eq!(a.cmd, "serve");
        assert_eq!(a.get("dir"), Some("models/"));
        assert_eq!(a.get("addr"), Some("127.0.0.1:0"));
        assert_eq!(a.get_usize("cache-mb", 64).unwrap(), 128);
        assert_eq!(a.get_count("workers", 1).unwrap(), 8);
        assert_eq!(a.get_count("read-timeout", 10_000).unwrap(), 300);
        assert_eq!(a.get_count("write-timeout", 30_000).unwrap(), 500);
        // a zero deadline would time out every request: usage error
        let a = Args::parse(&sv(&["serve", "--read-timeout", "0"])).unwrap();
        assert!(a.get_count("read-timeout", 10_000).is_err());
        let a = Args::parse(&sv(&["serve"])).unwrap();
        assert_eq!(a.get_count("read-timeout", 10_000).unwrap(), 10_000);
        // backend selection switches and the connection cap
        let a = Args::parse(&sv(&[
            "serve", "--dir", "models/", "--event-loop", "--max-connections", "1024",
        ]))
        .unwrap();
        assert!(a.has("event-loop"));
        assert!(!a.has("threaded"));
        assert_eq!(a.get("max-connections"), Some("1024"));
        assert_eq!(a.get_count("max-connections", 1).unwrap(), 1024);
        let a = Args::parse(&sv(&["serve", "--dir", "models/", "--threaded"])).unwrap();
        assert!(a.has("threaded"));
        assert_eq!(a.get("max-connections"), None);
        // a zero cap would shed every connection: usage error
        let a = Args::parse(&sv(&["serve", "--max-connections", "0"])).unwrap();
        assert!(a.get_count("max-connections", 1).is_err());
    }

    #[test]
    fn parses_fuzz_flags() {
        let a = Args::parse(&sv(&[
            "fuzz", "--target", "container", "--cases", "512", "--seed", "7",
            "--corpus", "fuzz_corpus", "--artifacts", "/tmp/crashers",
        ]))
        .unwrap();
        assert_eq!(a.cmd, "fuzz");
        assert_eq!(a.get_or("target", "all"), "container");
        assert_eq!(a.get_count("cases", 256).unwrap(), 512);
        assert_eq!(a.get_usize("seed", 42).unwrap(), 7);
        assert_eq!(a.get_or("corpus", "fuzz_corpus"), "fuzz_corpus");
        assert_eq!(a.get("artifacts"), Some("/tmp/crashers"));
        // defaults when everything is omitted
        let a = Args::parse(&sv(&["fuzz"])).unwrap();
        assert_eq!(a.get_or("target", "all"), "all");
        assert_eq!(a.get_count("cases", 256).unwrap(), 256);
        // --cases 0 is a usage error like every other count flag
        let a = Args::parse(&sv(&["fuzz", "--cases", "0"])).unwrap();
        assert!(a.get_count("cases", 256).is_err());
        // evolve-mode flags: --evolve is a switch, --max-time/--json take
        // values, and delta_apply parses as a target name
        let a = Args::parse(&sv(&[
            "fuzz", "--target", "delta_apply", "--evolve", "--max-time", "60",
            "--json", "BENCH_fuzz.json", "--artifacts", "fuzz_artifacts",
        ]))
        .unwrap();
        assert!(a.has("evolve"));
        assert_eq!(a.get_or("target", "all"), "delta_apply");
        assert_eq!(a.get_usize("max-time", 0).unwrap(), 60);
        assert_eq!(a.get_or("json", "BENCH_fuzz.json"), "BENCH_fuzz.json");
        // --max-time 0 is valid (no cap), unlike the count flags
        let a = Args::parse(&sv(&["fuzz", "--evolve", "--max-time", "0"])).unwrap();
        assert_eq!(a.get_usize("max-time", 0).unwrap(), 0);
        let a = Args::parse(&sv(&["fuzz"])).unwrap();
        assert!(!a.has("evolve"));
        // --hostile 0 stays valid for loadgen (an amount, not a count)
        let a = Args::parse(&sv(&["loadgen", "--hostile", "0"])).unwrap();
        assert_eq!(a.get_usize("hostile", 0).unwrap(), 0);
        let a = Args::parse(&sv(&["loadgen", "--hostile", "3"])).unwrap();
        assert_eq!(a.get_usize("hostile", 3).unwrap(), 3);
    }

    #[test]
    fn parses_delta_flags() {
        // main() folds `delta encode` into the command "delta-encode"
        let a = Args::parse(&sv(&[
            "delta-encode", "--parent", "base.dcbc", "--target", "new.dcbc",
            "--out", "d.dcbc", "--workers", "4",
        ]))
        .unwrap();
        assert_eq!(a.cmd, "delta-encode");
        assert_eq!(a.get("parent"), Some("base.dcbc"));
        assert_eq!(a.get("target"), Some("new.dcbc"));
        assert_eq!(a.get("out"), Some("d.dcbc"));
        assert_eq!(a.get_count("workers", 1).unwrap(), 4);
        let a = Args::parse(&sv(&[
            "delta-bench", "--parent", "b", "--target", "t", "--iters", "16",
        ]))
        .unwrap();
        assert_eq!(a.get_count("iters", 32).unwrap(), 16);
        assert_eq!(a.get_or("json", "BENCH_delta.json"), "BENCH_delta.json");
        // --iters 0 rejected through the uniform count validator
        let a = Args::parse(&sv(&["delta-bench", "--iters", "0"])).unwrap();
        assert!(a.get_count("iters", 32).is_err());
        // fetch --from and sweep --delta-from parse as plain value flags
        let a = Args::parse(&sv(&["fetch", "--url", "http://h/models/m", "--from", "b.dcbc"]))
            .unwrap();
        assert_eq!(a.get("from"), Some("b.dcbc"));
        let a = Args::parse(&sv(&[
            "sweep", "--arch", "vgg16", "--delta-from", "b.dcbc", "--out-delta", "d.dcbc",
        ]))
        .unwrap();
        assert_eq!(a.get("delta-from"), Some("b.dcbc"));
        assert_eq!(a.get("out-delta"), Some("d.dcbc"));
        // synth perturbation knobs
        let a = Args::parse(&sv(&[
            "synth", "--arch", "vgg16", "--perturb-density", "0.02",
            "--perturb-scale", "0.05", "--perturb-seed", "7",
        ]))
        .unwrap();
        assert!((a.get_f32("perturb-density", 0.0).unwrap() - 0.02).abs() < 1e-9);
        assert!((a.get_f32("perturb-scale", 0.05).unwrap() - 0.05).abs() < 1e-9);
        assert_eq!(a.get_usize("perturb-seed", 1).unwrap(), 7);
    }

    #[test]
    fn parses_fetch_and_loadgen_flags() {
        let a = Args::parse(&sv(&[
            "fetch", "--url", "http://127.0.0.1:8080/models/lenet5", "--layer", "fc1",
            "--out-dir", "/tmp/w",
        ]))
        .unwrap();
        assert_eq!(a.cmd, "fetch");
        assert_eq!(a.get("url"), Some("http://127.0.0.1:8080/models/lenet5"));
        assert_eq!(a.get("layer"), Some("fc1"));
        assert_eq!(a.get("out-dir"), Some("/tmp/w"));

        let a = Args::parse(&sv(&[
            "loadgen", "--url", "http://127.0.0.1:8080", "--clients", "32",
            "--requests", "16", "--out", "BENCH_serve.json",
        ]))
        .unwrap();
        assert_eq!(a.cmd, "loadgen");
        assert_eq!(a.get_count("clients", 8).unwrap(), 32);
        assert_eq!(a.get_count("requests", 32).unwrap(), 16);
        assert_eq!(a.get("out"), Some("BENCH_serve.json"));

        // open-loop rate and the connection-scaling sweep flags
        let a = Args::parse(&sv(&[
            "loadgen", "--url", "http://127.0.0.1:8080", "--rate", "250.5",
            "--connections-sweep", "1,64,1k,10k", "--sweep-requests", "5",
        ]))
        .unwrap();
        assert_eq!(a.get("rate"), Some("250.5"));
        assert_eq!(a.get("connections-sweep"), Some("1,64,1k,10k"));
        assert_eq!(a.get_count("sweep-requests", 3).unwrap(), 5);
        // both absent by default: closed loop, no sweep
        let a = Args::parse(&sv(&["loadgen", "--url", "http://h"])).unwrap();
        assert_eq!(a.get("rate"), None);
        assert_eq!(a.get("connections-sweep"), None);
    }
}
