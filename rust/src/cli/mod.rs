//! Hand-rolled CLI (clap is not in the offline registry).
//!
//! ```text
//! deepcabac table1 [--large] [--scale N] [--no-eval] [--sweep N] [--workers N]
//! deepcabac compress   --model NAME --out FILE [--s N | --sweep N] [--lambda-scale X]
//! deepcabac decompress --in FILE --out-dir DIR
//! deepcabac eval       --model NAME [--compressed FILE]
//! deepcabac anatomy    [--levels "1,0,-3,..."]
//! deepcabac sweep      --model NAME [--points N] [--lambda-scale X] --csv FILE
//! deepcabac synth      --arch vgg16 [--scale N] [--s N]
//! ```

use std::collections::HashMap;

#[derive(Debug)]
pub struct Args {
    pub cmd: String,
    flags: HashMap<String, String>,
    pub switches: Vec<String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Self, String> {
        if argv.is_empty() {
            return Err("no subcommand".into());
        }
        let cmd = argv[0].clone();
        let mut flags = HashMap::new();
        let mut switches = Vec::new();
        let mut i = 1;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    flags.insert(name.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    switches.push(name.to_string());
                    i += 1;
                }
            } else {
                return Err(format!("unexpected positional argument {a:?}"));
            }
        }
        Ok(Self { cmd, flags, switches })
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name} expects an integer")),
        }
    }

    pub fn get_f32(&self, name: &str, default: f32) -> Result<f32, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name} expects a float")),
        }
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }
}

pub const USAGE: &str = "\
deepcabac — context-adaptive binary arithmetic coding for DNN compression
(reproduction of Wiedemann et al., ICML 2019)

USAGE:
  deepcabac table1 [--large] [--scale N] [--no-eval] [--sweep N] [--workers N]
      Regenerate the paper's Table 1 (small trained models; --large adds
      the synthetic ImageNet-scale rows at 1/N channel scale).
  deepcabac compress --model NAME --out FILE [--s N | --sweep N]
                     [--lambda-scale X] [--workers N] [--chunks N]
      Compress a trained model from artifacts/ into a .dcbc container.
      --chunks N > 1 splits every tensor into N independently coded
      streams (container v2) so one giant layer encodes and decodes in
      parallel; N = 1 (default) keeps the original v1 bitstream.
  deepcabac decompress --in FILE --out-dir DIR
      Reconstruct weight tensors from a container into .npy files.
  deepcabac eval --model NAME [--compressed FILE]
      Accuracy/PSNR via the PJRT runtime (original or compressed weights).
  deepcabac anatomy [--levels L1,L2,...]
      Figure 1: per-bin trace of the binarization of a level sequence.
  deepcabac sweep --model NAME [--points N] [--lambda-scales a,b,c] [--csv FILE]
      Rate-distortion sweep over (S, λ) — the paper's §3/§4 trade-off.
  deepcabac synth --arch vgg16|resnet50|mobilenet [--scale N] [--s N]
      Generate + compress a synthetic ImageNet-scale model.
";

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_flags_and_switches() {
        let a = Args::parse(&sv(&["table1", "--scale", "8", "--large", "--no-eval"]))
            .unwrap();
        assert_eq!(a.cmd, "table1");
        assert_eq!(a.get_usize("scale", 1).unwrap(), 8);
        assert!(a.has("large"));
        assert!(a.has("no-eval"));
        assert!(!a.has("quick"));
    }

    #[test]
    fn rejects_positional() {
        assert!(Args::parse(&sv(&["compress", "stray"])).is_err());
    }

    #[test]
    fn typed_accessor_errors() {
        let a = Args::parse(&sv(&["x", "--n", "abc"])).unwrap();
        assert!(a.get_usize("n", 0).is_err());
    }
}
